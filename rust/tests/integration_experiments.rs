//! The experiment registry end-to-end: every paper figure/table regenerates
//! in fast mode and carries its expected structure.

use flatattention::coordinator::experiments;

#[test]
fn every_experiment_runs_fast() {
    for (id, _) in experiments::list() {
        let rep = experiments::run(id, true).unwrap_or_else(|e| panic!("{id}: {e}"));
        let text = rep.render();
        assert!(text.len() > 100, "{id}: suspiciously short report");
        assert!(!rep.rows.is_empty() || id == "tab3", "{id}: no rows");
    }
}

#[test]
fn fig7_reports_hw_advantage() {
    let rep = experiments::run("fig7", true).unwrap();
    let text = rep.render();
    // Large-transfer rows must show double-digit HW-vs-Seq speedups.
    assert!(text.contains("row multicast"));
    assert!(text.contains("row sum-reduce"));
    let has_big_speedup = rep.rows.iter().any(|r| {
        r.last()
            .and_then(|s| s.trim_end_matches('x').parse::<f64>().ok())
            .map(|v| v > 20.0)
            .unwrap_or(false)
    });
    assert!(has_big_speedup, "expected >20x HW-vs-SW.Seq rows:\n{text}");
}

#[test]
fn fig8_reports_flat_speedup_note() {
    let rep = experiments::run("fig8", true).unwrap();
    assert!(rep.rows.iter().any(|r| r.iter().any(|c| c == "FlatAsync")));
    assert!(rep.rows.iter().any(|r| r.iter().any(|c| c == "FA-2")));
}

#[test]
fn fig12_average_speedup_in_paper_range() {
    let rep = experiments::run("fig12", true).unwrap();
    let note = rep.notes.iter().find(|n| n.contains("average speedup")).expect("note");
    // Parse "average speedup X.Yx".
    let v: f64 = note
        .split("average speedup ")
        .nth(1)
        .and_then(|s| s.split('x').next())
        .and_then(|s| s.trim().parse().ok())
        .expect("parse");
    assert!(v > 1.2 && v < 3.5, "average speedup {v} (paper: 1.9x)");
}

#[test]
fn tab2_contains_all_four_systems() {
    let rep = experiments::run("tab2", true).unwrap();
    let text = rep.render();
    for name in ["CM384", "DS-Prof", "Ours1", "Ours2"] {
        assert!(text.contains(name), "missing {name}");
    }
}

#[test]
fn fig1a_attention_share_grows_with_context() {
    let rep = experiments::run("fig1a", true).unwrap();
    // DS671B decode rows: attention % must increase with len.
    let ds_rows: Vec<&Vec<String>> = rep
        .rows
        .iter()
        .filter(|r| r[0].contains("671B") && r[1] == "decode")
        .collect();
    assert!(ds_rows.len() >= 2);
    let pct = |s: &str| s.trim_end_matches('%').parse::<f64>().unwrap();
    assert!(pct(&ds_rows.last().unwrap()[3]) > pct(&ds_rows[0][3]));
}

#[test]
fn fig11_selects_128_slice() {
    let rep = experiments::run("fig11", true).unwrap();
    let row128 = rep.rows.iter().find(|r| r[0] == "128x128").unwrap();
    assert_eq!(row128.last().unwrap(), "yes");
    let row256 = rep.rows.iter().find(|r| r[0] == "256x256").unwrap();
    assert_eq!(row256.last().unwrap(), "NO");
}
