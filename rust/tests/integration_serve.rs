//! Serving-simulator invariants: request conservation, determinism under a
//! fixed seed, p99-TPOT monotonicity in offered load, KV-capacity safety
//! under both admission policies, and the Table II EP32-PP2 saturation knee
//! the acceptance criteria call for.

use flatattention::multichip::d2d::WaferSystem;
use flatattention::multichip::parallelism::KernelCache;
use flatattention::serve::request::{generate_trace, LengthProfile, TraceConfig, TrafficPattern};
use flatattention::serve::scheduler::{AdmissionPolicy, SchedulerConfig};
use flatattention::serve::sim::{load_sweep, saturation_knee, simulate, ServeConfig, StageTimeCache};
use flatattention::workload::deepseek::DeepSeekConfig;

fn patterns(horizon_s: f64) -> Vec<TrafficPattern> {
    vec![
        TrafficPattern::Poisson,
        TrafficPattern::Bursty { period_s: horizon_s / 5.0, duty: 0.3, burst_factor: 4.0 },
        TrafficPattern::Diurnal { period_s: horizon_s, trough_factor: 0.25 },
    ]
}

#[test]
fn requests_are_conserved_across_patterns_and_loads() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let cfg = ServeConfig::default();
    let kernels = KernelCache::new();
    let stages = StageTimeCache::new();
    for pattern in patterns(5.0) {
        let outcomes =
            load_sweep(&sys, &ds, &cfg, pattern, &[250.0, 2000.0], 11, 5.0, &kernels, &stages);
        for o in &outcomes {
            // arrived = completed + rejected + in-flight + queued at horizon.
            assert!(o.conserves_requests(), "conservation violated: {o:?}");
            assert!(o.arrived <= o.offered);
            assert!(!o.kv_over_capacity, "{} @ {} overflowed KV", o.pattern, o.offered_rps);
            assert!(o.completed > 0, "{} @ {}: nothing completed", o.pattern, o.offered_rps);
        }
    }
}

#[test]
fn simulation_is_deterministic_under_fixed_seed() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let cfg = ServeConfig::default();
    // Two fully independent runs (fresh caches each) — thread scheduling and
    // cache population order must not leak into any reported number.
    let run = || {
        load_sweep(
            &sys,
            &ds,
            &cfg,
            TrafficPattern::Bursty { period_s: 3.0, duty: 0.3, burst_factor: 4.0 },
            &[500.0, 1500.0],
            2026,
            4.0,
            &KernelCache::new(),
            &StageTimeCache::new(),
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed must reproduce the outcome bit-for-bit");
    // And per-request records replay too.
    let trace = generate_trace(&TraceConfig::new(9, TrafficPattern::Poisson, 300.0, 3.0));
    let (_, recs_a) = simulate(&sys, &ds, &trace, &cfg, 3.0, "p", 300.0, &KernelCache::new(), &StageTimeCache::new());
    let (_, recs_b) = simulate(&sys, &ds, &trace, &cfg, 3.0, "p", 300.0, &KernelCache::new(), &StageTimeCache::new());
    assert_eq!(recs_a, recs_b);
}

#[test]
fn p99_tpot_is_monotone_in_offered_load_with_saturation_knee() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let cfg = ServeConfig::default();
    let rates = [250.0, 1000.0, 2000.0, 4000.0];
    let outcomes = load_sweep(
        &sys,
        &ds,
        &cfg,
        TrafficPattern::Poisson,
        &rates,
        2026,
        10.0,
        &KernelCache::new(),
        &StageTimeCache::new(),
    );
    for o in &outcomes {
        assert!(o.completed > 100, "{} rps: only {} completed", o.offered_rps, o.completed);
        assert!(o.conserves_requests());
    }
    // Coupled thinning makes the load axis a refinement: p99 TPOT must be
    // non-decreasing (small slack for batch/kv bucket boundaries).
    for w in outcomes.windows(2) {
        assert!(
            w[1].tpot_ms.p99 >= 0.9 * w[0].tpot_ms.p99,
            "p99 TPOT regressed with load: {} rps → {:.1} ms, {} rps → {:.1} ms",
            w[0].offered_rps,
            w[0].tpot_ms.p99,
            w[1].offered_rps,
            w[1].tpot_ms.p99
        );
    }
    assert!(
        outcomes.last().unwrap().tpot_ms.p99 > outcomes[0].tpot_ms.p99,
        "overload must visibly degrade p99 TPOT"
    );
    // The acceptance-criteria knee on the Table II EP32-PP2 configuration:
    // under-SLO at the bottom of the sweep, past the 50 ms SLO at the top.
    assert!(outcomes[0].tpot_ms.p99 < cfg.slo_tpot_ms, "light load p99 {:.1} ms", outcomes[0].tpot_ms.p99);
    assert!(
        outcomes.last().unwrap().tpot_ms.p99 > cfg.slo_tpot_ms,
        "saturated p99 {:.1} ms should exceed the SLO",
        outcomes.last().unwrap().tpot_ms.p99
    );
    let knee = saturation_knee(&outcomes, cfg.slo_tpot_ms).expect("sweep must exhibit a knee");
    assert!(knee > rates[0] && knee <= *rates.last().unwrap(), "knee at {knee} rps");
    // Goodput collapses past the knee relative to offered load.
    let last = outcomes.last().unwrap();
    assert!(last.goodput_rps < 0.9 * last.offered_rps, "goodput {:.0} at {:.0} rps", last.goodput_rps, last.offered_rps);
}

#[test]
fn kv_occupancy_never_exceeds_capacity_under_pressure() {
    let ds = DeepSeekConfig::v3_671b();
    // Memory-starved wafer: 20 GiB HBM/chip leaves ~2.5 GiB for KV after
    // weights, so both policies hit the capacity wall hard.
    let mut sys = WaferSystem::paper();
    sys.chip.hbm.capacity_gib_per_stack = 10;
    let mut tc = TraceConfig::new(5, TrafficPattern::Poisson, 2500.0, 8.0);
    tc.lengths = LengthProfile::decode_heavy();
    let trace = generate_trace(&tc);
    let kernels = KernelCache::new();
    let stages = StageTimeCache::new();
    for policy in [AdmissionPolicy::ReserveFull, AdmissionPolicy::OnDemandPreempt] {
        let cfg = ServeConfig {
            scheduler: SchedulerConfig { policy, ..Default::default() },
            ..Default::default()
        };
        let (o, _) = simulate(&sys, &ds, &trace, &cfg, 8.0, "pressure", 2500.0, &kernels, &stages);
        assert!(!o.kv_over_capacity, "{policy:?} overflowed KV");
        assert!(o.peak_kv_occupancy <= 1.0 + 1e-9, "{policy:?} peak {}", o.peak_kv_occupancy);
        assert!(o.peak_kv_occupancy > 0.5, "{policy:?} never came under pressure: peak {}", o.peak_kv_occupancy);
        assert!(o.conserves_requests());
        match policy {
            AdmissionPolicy::ReserveFull => {
                assert_eq!(o.preemptions, 0, "reserve-full must never preempt")
            }
            AdmissionPolicy::OnDemandPreempt => {
                assert!(o.preemptions > 0, "on-demand under pressure must preempt")
            }
        }
    }
}

#[test]
fn serve_experiments_render() {
    for id in ["serve_load", "serve_policies", "serve_prefix"] {
        let rep = flatattention::coordinator::experiments::run(id, true)
            .unwrap_or_else(|e| panic!("{id}: {e}"));
        let text = rep.render();
        assert!(text.len() > 200, "{id}: short report\n{text}");
        assert!(!rep.rows.is_empty(), "{id}: no rows");
    }
    // The full registry advertises the serving experiments.
    let ids: Vec<&str> = flatattention::coordinator::experiments::list().iter().map(|(i, _)| *i).collect();
    assert!(ids.contains(&"serve_load") && ids.contains(&"serve_policies") && ids.contains(&"serve_prefix"));
}

#[test]
fn serve_prefix_experiment_is_deterministic() {
    // Acceptance criterion: serve_prefix reports prefix-cache hit rate and
    // TTFT deltas deterministically at its fixed seed — two fresh runs
    // render the identical table.
    let a = flatattention::coordinator::experiments::run("serve_prefix", true).unwrap();
    let b = flatattention::coordinator::experiments::run("serve_prefix", true).unwrap();
    assert_eq!(a.render(), b.render());
    assert!(a.render().contains("hit rate"), "report must surface the hit rate");
}
