//! Observability-layer invariants: well-nested causal request lifecycles,
//! the handoff-follows-prefill causality anchor on disaggregated fleets,
//! span/counter conservation against the end-of-run aggregates, the exact
//! link busy-fraction integral reconstructed from handoff spans, fixed-seed
//! byte-identical exports (the acceptance criterion), the guarantee that
//! attaching a sink never changes a simulation result, and the performance
//! -attribution layer's conservation anchors: latency-waterfall segments
//! sum to the measured TTFT/decode span, per-kernel attributed time sums to
//! engine busy time, attrib exports replay byte-identically at any shard
//! count, and the report's dataflow anchor stays pinned to the Fig. 9
//! operating point.

use flatattention::arch::config::{ChipConfig, Dtype, SimFidelity};
use flatattention::cluster::{
    simulate_cluster, simulate_cluster_observed, simulate_cluster_profiled, ClusterConfig, FaultPlan,
};
use flatattention::dataflow::{simulate_attention, AttentionDataflow, FlatParams, FlatTiling};
use flatattention::multichip::d2d::WaferSystem;
use flatattention::multichip::parallelism::KernelCache;
use flatattention::obs::report::{dataflow_anchor, render_attrib_report};
use flatattention::obs::{AttribExport, ObsBundle, ObsConfig, Span, TraceRecorder, Waterfall};
use flatattention::serve::request::{generate_trace, PrefixProfile, TraceConfig, TrafficPattern};
use flatattention::serve::sim::{assemble_serve_attrib, simulate, simulate_observed, ServeConfig, StageTimeCache};
use flatattention::workload::attention::AttentionShape;
use flatattention::workload::deepseek::DeepSeekConfig;

const EPS: f64 = 1e-9;

fn trace(rate: f64, horizon: f64, seed: u64) -> Vec<flatattention::serve::request::Request> {
    generate_trace(&TraceConfig::new(seed, TrafficPattern::Poisson, rate, horizon))
}

fn arg<'a>(s: &'a Span, key: &str) -> Option<&'a str> {
    s.args.iter().find(|(k, _)| *k == key).map(|(_, v)| v.as_str())
}

/// Spans and instants must be well-formed, and the lifecycle spans on each
/// request lane must tile time without overlap (queued → prefill → decode
/// are sequential — the recorder's one-open-span-per-tid discipline).
fn assert_well_nested(r: &TraceRecorder) {
    for s in r.spans() {
        assert!(s.end_s >= s.start_s, "span {} on pid {} tid {} ends before it starts", s.name, s.pid, s.tid);
        assert!(s.start_s >= 0.0 && s.end_s.is_finite());
    }
    let mut tids: Vec<u64> = r.spans().iter().map(|s| s.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    for tid in tids {
        // Recording order is chronological within a lane.
        let lane: Vec<&Span> = r.spans().iter().filter(|s| s.tid == tid && s.cat == "lifecycle").collect();
        for w in lane.windows(2) {
            assert!(
                w[1].start_s >= w[0].end_s - EPS,
                "overlapping lifecycle spans on pid {} tid {tid}: {} [{}, {}] then {} [{}, {}]",
                r.pid(),
                w[0].name,
                w[0].start_s,
                w[0].end_s,
                w[1].name,
                w[1].start_s,
                w[1].end_s
            );
        }
    }
}

#[test]
fn serve_spans_are_well_nested_and_causal() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let t = trace(400.0, 3.0, 11);
    let cfg = ServeConfig::default();
    let (o, records, obs) = simulate_observed(
        &sys,
        &ds,
        &t,
        &cfg,
        3.0,
        "poisson",
        400.0,
        &kernels,
        &stages,
        ObsConfig::default(),
    );
    assert!(o.completed > 0, "need completions to make the test meaningful");
    assert_well_nested(&obs.trace);
    // Wave spans on the engine lane advance monotonically.
    let waves: Vec<_> = obs.trace.spans().iter().filter(|s| s.name == "wave").collect();
    assert_eq!(waves.len() as u64, o.ticks);
    for w in waves.windows(2) {
        assert!(w[1].start_s >= w[0].end_s - EPS, "wave ticks must not overlap");
    }
    // Every request lane's spans sit between arrival and completion (or the
    // horizon), and first_token instants land inside the request lifetime.
    for (rec, r) in records.iter().enumerate() {
        let tid = rec as u64 + 1;
        for s in obs.trace.spans().iter().filter(|s| s.tid == tid) {
            assert!(s.start_s >= r.arrival_s - EPS, "req {} span {} starts before arrival", r.id, s.name);
            if let Some(c) = r.completion_s {
                assert!(s.end_s <= c + EPS, "req {} span {} outlives completion", r.id, s.name);
            }
        }
        if let Some(f) = r.first_token_s {
            let inst = obs
                .trace
                .instants()
                .iter()
                .find(|i| i.tid == tid && i.name == "first_token")
                .unwrap_or_else(|| panic!("req {} got a first token but no instant", r.id));
            assert!((inst.t_s - f).abs() < EPS);
        }
    }
    // No span lost: the recorder never hit its (generous) cap.
    assert_eq!(obs.trace.dropped(), 0);
}

#[test]
fn serve_span_outcomes_and_counters_match_the_aggregate() {
    // The conservation anchor: spans closed with outcome=completed /
    // rejected and the monotonic counters must agree exactly with the
    // ServeOutcome the same run aggregates.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let t = trace(700.0, 3.0, 2026);
    let cfg = ServeConfig::default();
    let (o, _, obs) = simulate_observed(
        &sys,
        &ds,
        &t,
        &cfg,
        3.0,
        "poisson",
        700.0,
        &kernels,
        &stages,
        ObsConfig::default(),
    );
    let outcome_count = |which: &str| obs.trace.spans().iter().filter(|s| arg(s, "outcome") == Some(which)).count();
    assert_eq!(outcome_count("completed"), o.completed, "completed spans vs aggregate");
    assert_eq!(outcome_count("rejected"), o.rejected, "rejected spans vs aggregate");
    // In-flight + queued work at the horizon is exactly what close_open
    // marked unfinished (preempted-and-requeued lanes land here too).
    assert_eq!(outcome_count("unfinished"), o.in_flight + o.queued, "unfinished spans vs backlog");
    assert_eq!(obs.counters.get("completed"), o.completed as u64);
    assert_eq!(obs.counters.get("rejected"), o.rejected as u64);
    assert_eq!(obs.counters.get("arrivals"), o.arrived as u64);
    assert_eq!(obs.counters.get("preempted"), o.preemptions);
    assert_eq!(obs.counters.get("waves"), o.ticks);
    assert_eq!(
        obs.counters.get("first_tokens"),
        obs.trace.instants().iter().filter(|i| i.name == "first_token").count() as u64
    );
    // Gauges: sample times advance monotonically, fractions stay in [0, 1].
    for w in obs.series.rows().windows(2) {
        assert!(w[1].t_s >= w[0].t_s);
    }
    for row in obs.series.rows() {
        assert!((0.0..=1.0).contains(&row.prefix_hit_rate));
        assert!(row.kv_frac >= 0.0);
    }
}

#[test]
fn cluster_handoffs_follow_prefill_and_bundle_conserves() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let t = trace(300.0, 3.0, 5);
    let ccfg = ClusterConfig::disaggregated(1, 1, &ds);
    let (o, _, bundle) = simulate_cluster_observed(
        &sys,
        &ds,
        &t,
        &ccfg,
        3.0,
        300.0,
        &kernels,
        &stages,
        Some(ObsConfig::default()),
    );
    let bundle = bundle.expect("a sink was requested");
    // pid layout: entry pool, decode pool, then the fleet lane.
    assert_eq!(bundle.traces.len(), 3);
    assert_eq!(bundle.traces[0].process_name(), "prefill-0");
    assert_eq!(bundle.traces[1].process_name(), "decode-0");
    assert_eq!(bundle.traces[2].process_name(), "fleet");
    for r in &bundle.traces {
        assert_well_nested(r);
    }
    let fleet = &bundle.traces[2];
    let handoffs: Vec<&Span> = fleet.spans().iter().filter(|s| s.name == "handoff").collect();
    assert!(o.migrated > 0, "disaggregated run must migrate KV");
    assert_eq!(handoffs.len(), o.migrated, "one handoff span per migration");
    // Causality: every KV handoff starts at/after the end of a finished
    // prefill span for the same request on the entry pool.
    for h in &handoffs {
        let req = arg(h, "req").expect("handoff spans carry the request id");
        let prefill_done = bundle.traces[0]
            .spans()
            .iter()
            .any(|s| s.name == "prefill" && arg(s, "req") == Some(req) && s.end_s <= h.start_s + EPS);
        assert!(prefill_done, "handoff for req {req} starts before its prefill ended");
        assert!(arg(h, "bytes").is_some() && arg(h, "link_wait_s").is_some());
    }
    // Router telemetry: one route instant per processed arrival, spill
    // count mirrored into the counters.
    let routes = fleet.instants().iter().filter(|i| i.name == "route").count();
    assert_eq!(routes as u64, bundle.counters.get("routed"));
    assert!(bundle.counters.get("routed") > 0);
    assert_eq!(bundle.counters.get("handoffs"), o.migrated as u64);
    assert_eq!(bundle.counters.get("migrated"), o.migrated as u64);

    // Conservation on a colocated fleet, where entry completions ARE the
    // end-to-end completions: completed/rejected spans across every
    // instance recorder match the ClusterOutcome exactly.
    let ccfg = ClusterConfig::colocated(2, &ds);
    let (o, _, bundle) = simulate_cluster_observed(
        &sys,
        &ds,
        &t,
        &ccfg,
        3.0,
        300.0,
        &kernels,
        &stages,
        Some(ObsConfig::default()),
    );
    let bundle = bundle.expect("a sink was requested");
    let count = |which: &str| {
        bundle
            .traces
            .iter()
            .flat_map(|r| r.spans())
            .filter(|s| arg(s, "outcome") == Some(which))
            .count()
    };
    assert!(o.conserves_requests());
    assert_eq!(count("completed"), o.completed);
    assert_eq!(count("rejected"), o.rejected);
    assert_eq!(bundle.counters.get("completed"), o.completed as u64);
}

#[test]
fn link_busy_fraction_is_the_exact_interval_integral() {
    // The exact `SharedLink::busy_fraction` anchor: the reported link
    // telemetry must equal the time-in-window integral of per-migration
    // occupancy, reconstructed independently from the handoff spans
    // (span start = prefill completion; occupancy = [start + queue wait,
    // + serialization) clamped to the horizon). A single slow flow makes
    // the reconstruction see real queueing and horizon-clipped transfers.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let horizon = 3.0;
    let mut ccfg = ClusterConfig::disaggregated(1, 1, &ds);
    ccfg.transfer.parallel_flows = 1;
    ccfg.transfer.link_bandwidth_bytes_per_s = 2.0e9;
    let t = trace(400.0, horizon, 17);
    let (o, _, bundle) = simulate_cluster_observed(
        &sys,
        &ds,
        &t,
        &ccfg,
        horizon,
        400.0,
        &KernelCache::new(),
        &StageTimeCache::new(),
        Some(ObsConfig::default()),
    );
    let bundle = bundle.expect("a sink was requested");
    assert!(o.migrated > 0 && o.link_wait_s > 0.0, "the regime must queue the link");
    let fleet = bundle.traces.last().expect("fleet lane");
    let mut in_window = 0.0f64;
    let mut handoffs = 0usize;
    for s in fleet.spans().iter().filter(|s| s.name == "handoff") {
        handoffs += 1;
        let bytes: f64 = arg(s, "bytes").unwrap().parse().unwrap();
        let wait: f64 = arg(s, "link_wait_s").unwrap().parse().unwrap();
        let ser = bytes / ccfg.transfer.link_bandwidth_bytes_per_s;
        let start = s.start_s + wait;
        in_window += (start + ser).min(horizon).max(0.0) - start.clamp(0.0, horizon);
    }
    assert_eq!(handoffs, o.migrated, "one handoff span per migration");
    let expect = (in_window / (horizon * ccfg.transfer.parallel_flows as f64)).min(1.0);
    assert!(
        (o.link_busy_frac - expect).abs() < 1e-5,
        "busy fraction {} disagrees with the reconstructed integral {expect}",
        o.link_busy_frac
    );
    assert!(o.link_busy_frac > 0.0 && o.link_busy_frac <= 1.0);
}

#[test]
fn same_seed_runs_export_byte_identical_artifacts() {
    // The acceptance criterion: no wall clock, no map-order dependence —
    // two fresh same-seed runs render byte-identical artifacts, for both
    // the standalone engine and the disaggregated fleet.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let serve_run = || {
        let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
        let t = trace(500.0, 2.5, 77);
        let cfg = ServeConfig::default();
        let (_, _, obs) = simulate_observed(
            &sys,
            &ds,
            &t,
            &cfg,
            2.5,
            "poisson",
            500.0,
            &kernels,
            &stages,
            ObsConfig::default(),
        );
        let mut b = ObsBundle::new();
        b.push_engine(*obs);
        b.exports()
    };
    let (a, b) = (serve_run(), serve_run());
    assert_eq!(a.trace_json, b.trace_json, "serve trace must replay byte-identically");
    assert_eq!(a.series_csv, b.series_csv);
    assert_eq!(a.series_json, b.series_json);
    assert_eq!(a.metrics_text, b.metrics_text);
    assert!(a.trace_json.contains("\"traceEvents\":["));
    assert!(a.metrics_text.contains("flatattention_completed_total"));

    let cluster_run = || {
        let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
        let t = generate_trace(
            &TraceConfig::new(77, TrafficPattern::Poisson, 300.0, 2.5).with_prefixes(PrefixProfile::agentic()),
        );
        let ccfg = ClusterConfig::disaggregated(1, 2, &ds);
        let (_, _, bundle) = simulate_cluster_observed(
            &sys,
            &ds,
            &t,
            &ccfg,
            2.5,
            300.0,
            &kernels,
            &stages,
            Some(ObsConfig::default()),
        );
        bundle.expect("a sink was requested").exports()
    };
    let (a, b) = (cluster_run(), cluster_run());
    assert_eq!(a.trace_json, b.trace_json, "cluster trace must replay byte-identically");
    assert_eq!(a.series_csv, b.series_csv);
    assert_eq!(a.series_json, b.series_json);
    assert_eq!(a.metrics_text, b.metrics_text);
}

#[test]
fn attaching_a_sink_never_changes_the_simulation() {
    // Observability must be a pure observer: the instrumented run's outcome
    // and per-request records equal the plain run's bit for bit.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let t = trace(450.0, 3.0, 9);
    let cfg = ServeConfig::default();
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let (plain, plain_recs) = simulate(&sys, &ds, &t, &cfg, 3.0, "poisson", 450.0, &kernels, &stages);
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let (observed, observed_recs, _) = simulate_observed(
        &sys,
        &ds,
        &t,
        &cfg,
        3.0,
        "poisson",
        450.0,
        &kernels,
        &stages,
        ObsConfig::default(),
    );
    assert_eq!(plain, observed, "the sink changed the serve outcome");
    assert_eq!(plain_recs, observed_recs);

    let ccfg = ClusterConfig::disaggregated(1, 1, &ds);
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let (plain, plain_recs) = simulate_cluster(&sys, &ds, &t, &ccfg, 3.0, 450.0, &kernels, &stages);
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let (observed, observed_recs, bundle) = simulate_cluster_observed(
        &sys,
        &ds,
        &t,
        &ccfg,
        3.0,
        450.0,
        &kernels,
        &stages,
        Some(ObsConfig::default()),
    );
    assert!(bundle.is_some());
    assert_eq!(plain, observed, "the sink changed the cluster outcome");
    assert_eq!(plain_recs, observed_recs);
}

/// The waterfall conservation anchor: the additive identities hold to 1e-9
/// on every delivered request, serve and cluster alike. Signs of the two
/// residual segments (`requeue_stall_s`, `interference_s`) are NOT pinned —
/// a request requeued after its first token can legitimately carry a
/// negative stall against its second-life slot.
fn assert_waterfalls_conserve(wfs: &[Waterfall]) {
    assert!(!wfs.is_empty(), "need waterfalls to make the test meaningful");
    for w in wfs {
        let ttft = w.queue_wait_s + w.prefill_s + w.link_wait_s + w.requeue_stall_s;
        assert!(
            (w.ttft_s - ttft).abs() < EPS,
            "ttft segments do not sum for req {}: {ttft} vs {}",
            w.id,
            w.ttft_s
        );
        let span = w.decode_solo_s + w.interference_s;
        assert!((w.decode_span_s - span).abs() < EPS, "decode segments do not sum for req {}", w.id);
        assert!(w.ttft_s >= -EPS, "first token before arrival for req {}", w.id);
        if !w.completed {
            assert!(w.decode_span_s.abs() < EPS, "an unfinished request has no decode span");
        }
    }
}

#[test]
fn serve_attribution_conserves_waterfalls_and_kernel_time() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let t = trace(500.0, 3.0, 21);
    let cfg = ServeConfig::default();
    let (o, records, obs) = simulate_observed(
        &sys,
        &ds,
        &t,
        &cfg,
        3.0,
        "poisson",
        500.0,
        &kernels,
        &stages,
        ObsConfig::default(),
    );
    assert!(o.completed > 0, "need completions to make the test meaningful");
    let x = assemble_serve_attrib(&records, &obs);
    // Per-kernel attributed time sums to engine busy time: every settled
    // stage bills its full measured seconds (residual → the other class).
    let busy = x.busy_s();
    assert!(busy > 0.0, "a loaded run must accumulate busy time");
    assert!(
        (x.kernels.total_s() - busy).abs() <= EPS * busy.max(1.0),
        "kernel time {} must sum to engine busy time {busy}",
        x.kernels.total_s()
    );
    // One waterfall per delivered request; identities to 1e-9.
    let delivered = records.iter().filter(|r| r.first_token_s.is_some()).count();
    assert_eq!(x.waterfalls.len(), delivered);
    assert_eq!(x.offered, records.len());
    assert_waterfalls_conserve(&x.waterfalls);
    // A single-engine serve run never sees the fleet-only segments.
    for w in &x.waterfalls {
        assert!(w.link_wait_s.abs() < EPS && w.requeues == 0);
    }
}

#[test]
fn cluster_attribution_conserves_and_replays_byte_identically_at_any_shard_count() {
    // The fleet-level acceptance anchors in one run: a faulted
    // disaggregated fleet whose waterfalls conserve, whose per-engine
    // kernel time sums to per-engine busy time, and whose attribution
    // export replays byte-identically at every shard count (the DES
    // self-profile is the only wall-clock piece, and it never leaks in).
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let (horizon, rate) = (3.0, 400.0);
    let t = generate_trace(
        &TraceConfig::new(31, TrafficPattern::Poisson, rate, horizon).with_prefixes(PrefixProfile::agentic()),
    );
    let mut base = ClusterConfig::disaggregated(1, 2, &ds);
    base.transfer = flatattention::cluster::KvTransferModel::d2d_class(&ds, base.serve.dtype);
    // Kill decode instance 1 (gid 2) mid-run; restart shortly after.
    let plan = FaultPlan::none().kill(2, horizon * 0.5).with_restart(0.25);
    let run = |shards: u32| {
        let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
        let cfg = ClusterConfig { shards, ..base };
        let (o, records, bundle, profile) = simulate_cluster_profiled(
            &sys,
            &ds,
            &t,
            &cfg,
            &plan,
            horizon,
            rate,
            &kernels,
            &stages,
            Some(ObsConfig::default()),
        );
        assert!(o.conserves_requests(), "conservation violated at {shards} shard(s)");
        (o, records, bundle.expect("a sink was requested"), profile)
    };
    let (o, records, bundle, profile) = run(1);
    let x = &bundle.attrib;
    assert!(o.completed > 0 && o.requeued > 0, "the kill must strand work: {o:?}");
    assert_waterfalls_conserve(&x.waterfalls);
    assert!(x.waterfalls.iter().any(|w| w.requeues > 0), "a requeued request must surface in a waterfall");
    assert!(x.waterfalls.iter().any(|w| w.link_wait_s > 0.0), "disaggregated handoffs must surface link wait");
    assert_eq!(x.offered, t.len());
    // Per-engine conservation, and therefore the run-level aggregate too.
    assert!(x.busy_s() > 0.0);
    for e in &x.engines {
        assert!(
            (e.kernels.total_s() - e.busy_s).abs() <= EPS * e.busy_s.max(1.0),
            "engine {} kernel time diverged from its busy time",
            e.pid
        );
    }
    assert!((x.kernels.total_s() - x.busy_s()).abs() <= EPS * x.busy_s().max(1.0));
    // The DES self-profile is wall-clock (report-note-only), but its shape
    // is pinned: one lane per worker, a nonzero epoch count.
    assert!(profile.epochs > 0 && profile.workers >= 1);
    assert_eq!(profile.worker_busy_s.len(), profile.workers);
    assert_eq!(profile.barrier_stall_s.len(), profile.workers);
    let json1 = x.to_json();
    assert!(json1.contains("\"schema\":\"flatattention-attrib-v1\""));
    for shards in [2u32, 4] {
        let (mut o_s, records_s, bundle_s, _) = run(shards);
        o_s.shards = 1;
        assert_eq!(o_s, o, "outcome diverged at {shards} shard(s)");
        assert_eq!(records_s, records, "per-request records diverged at {shards} shard(s)");
        assert_eq!(bundle_s.attrib.to_json(), json1, "attrib export diverged at {shards} shard(s)");
    }
}

#[test]
fn attrib_exports_replay_byte_identically_and_flow_into_obs_exports() {
    // Same-seed serve runs render byte-identical attribution artifacts,
    // and the attribution rides the ObsExports bundle like every other
    // export format.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let run = || {
        let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
        let t = trace(500.0, 2.5, 77);
        let cfg = ServeConfig::default();
        let (_, records, obs) = simulate_observed(
            &sys,
            &ds,
            &t,
            &cfg,
            2.5,
            "poisson",
            500.0,
            &kernels,
            &stages,
            ObsConfig::default(),
        );
        let mut b = ObsBundle::new();
        b.attrib = assemble_serve_attrib(&records, &obs);
        b.push_engine(*obs);
        b.exports()
    };
    let (a, b) = (run(), run());
    assert_eq!(a.attrib_json, b.attrib_json, "serve attrib must replay byte-identically");
    assert!(a.attrib_json.contains("\"schema\":\"flatattention-attrib-v1\""));
    assert!(a.attrib_json.contains("\"waterfalls\":[{"), "a loaded run must export waterfalls");
    assert!(a.attrib_json.contains("\"kernels\":[{"), "a loaded run must export kernel rows");
    // A bundle with no attribution still renders a valid (empty) artifact.
    let empty = ObsBundle::new().exports();
    assert!(empty.attrib_json.contains("flatattention-attrib-v1"));
    assert!(AttribExport::default().is_empty());
}

#[test]
fn report_anchor_agrees_with_the_fig9_operating_point_within_1pct() {
    // The profiler's printed dataflow anchor must be the Table-II operating
    // point the golden Fig. 9 test pins — computed here independently from
    // first principles, agreement within 1%.
    let cfg = ChipConfig::table1();
    let shape = AttentionShape::mha_prefill(4, 32, 128, 4096, Dtype::Fp16);
    let tiling = FlatTiling { gx: 32, gy: 32, slice_r: 128, slice_c: 128 };
    let golden =
        simulate_attention(&cfg, &shape, AttentionDataflow::Flat(FlatParams::flat_async(tiling)), SimFidelity::Full);
    assert!(golden.matrix_efficiency_active > 0.80, "the Fig. 9 op point regressed");
    let anchor = dataflow_anchor();
    let rel = (anchor.matrix_efficiency_active - golden.matrix_efficiency_active).abs()
        / golden.matrix_efficiency_active;
    assert!(
        rel < 0.01,
        "report anchor {} diverged from the Fig. 9 op point {}",
        anchor.matrix_efficiency_active,
        golden.matrix_efficiency_active
    );
    // And the rendered profile carries exactly that number.
    let text = render_attrib_report("anchor", &AttribExport::default(), None);
    assert!(text.contains("dataflow anchor"));
    assert!(
        text.contains(&flatattention::metrics::fmt_pct(golden.matrix_efficiency_active)),
        "the report must print the anchor efficiency"
    );
}

#[test]
fn span_cap_drops_are_accounted_in_every_export() {
    // A tiny cap forces drops; the count must surface in the trace header
    // and the Prometheus counters rather than vanish.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let t = trace(400.0, 2.0, 3);
    let cfg = ServeConfig::default();
    let tiny = ObsConfig { span_cap: 8, ..ObsConfig::default() };
    let (_, _, obs) = simulate_observed(&sys, &ds, &t, &cfg, 2.0, "poisson", 400.0, &kernels, &stages, tiny);
    assert!(obs.trace.dropped() > 0, "the tiny cap must actually drop events");
    let dropped = obs.trace.dropped();
    let mut b = ObsBundle::new();
    b.push_engine(*obs);
    let e = b.exports();
    assert!(e.trace_json.contains(&format!("\"dropped_events\":\"{dropped}\"")));
    assert!(e.metrics_text.contains(&format!("flatattention_trace_events_dropped_total {dropped}")));
}
