//! Observability-layer invariants: well-nested causal request lifecycles,
//! the handoff-follows-prefill causality anchor on disaggregated fleets,
//! span/counter conservation against the end-of-run aggregates, the exact
//! link busy-fraction integral reconstructed from handoff spans, fixed-seed
//! byte-identical exports (the acceptance criterion), and the guarantee
//! that attaching a sink never changes a simulation result.

use flatattention::cluster::{simulate_cluster, simulate_cluster_observed, ClusterConfig};
use flatattention::multichip::d2d::WaferSystem;
use flatattention::multichip::parallelism::KernelCache;
use flatattention::obs::{ObsBundle, ObsConfig, Span, TraceRecorder};
use flatattention::serve::request::{generate_trace, PrefixProfile, TraceConfig, TrafficPattern};
use flatattention::serve::sim::{simulate, simulate_observed, ServeConfig, StageTimeCache};
use flatattention::workload::deepseek::DeepSeekConfig;

const EPS: f64 = 1e-9;

fn trace(rate: f64, horizon: f64, seed: u64) -> Vec<flatattention::serve::request::Request> {
    generate_trace(&TraceConfig::new(seed, TrafficPattern::Poisson, rate, horizon))
}

fn arg<'a>(s: &'a Span, key: &str) -> Option<&'a str> {
    s.args.iter().find(|(k, _)| *k == key).map(|(_, v)| v.as_str())
}

/// Spans and instants must be well-formed, and the lifecycle spans on each
/// request lane must tile time without overlap (queued → prefill → decode
/// are sequential — the recorder's one-open-span-per-tid discipline).
fn assert_well_nested(r: &TraceRecorder) {
    for s in r.spans() {
        assert!(s.end_s >= s.start_s, "span {} on pid {} tid {} ends before it starts", s.name, s.pid, s.tid);
        assert!(s.start_s >= 0.0 && s.end_s.is_finite());
    }
    let mut tids: Vec<u64> = r.spans().iter().map(|s| s.tid).collect();
    tids.sort_unstable();
    tids.dedup();
    for tid in tids {
        // Recording order is chronological within a lane.
        let lane: Vec<&Span> = r.spans().iter().filter(|s| s.tid == tid && s.cat == "lifecycle").collect();
        for w in lane.windows(2) {
            assert!(
                w[1].start_s >= w[0].end_s - EPS,
                "overlapping lifecycle spans on pid {} tid {tid}: {} [{}, {}] then {} [{}, {}]",
                r.pid(),
                w[0].name,
                w[0].start_s,
                w[0].end_s,
                w[1].name,
                w[1].start_s,
                w[1].end_s
            );
        }
    }
}

#[test]
fn serve_spans_are_well_nested_and_causal() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let t = trace(400.0, 3.0, 11);
    let cfg = ServeConfig::default();
    let (o, records, obs) = simulate_observed(
        &sys,
        &ds,
        &t,
        &cfg,
        3.0,
        "poisson",
        400.0,
        &kernels,
        &stages,
        ObsConfig::default(),
    );
    assert!(o.completed > 0, "need completions to make the test meaningful");
    assert_well_nested(&obs.trace);
    // Wave spans on the engine lane advance monotonically.
    let waves: Vec<_> = obs.trace.spans().iter().filter(|s| s.name == "wave").collect();
    assert_eq!(waves.len() as u64, o.ticks);
    for w in waves.windows(2) {
        assert!(w[1].start_s >= w[0].end_s - EPS, "wave ticks must not overlap");
    }
    // Every request lane's spans sit between arrival and completion (or the
    // horizon), and first_token instants land inside the request lifetime.
    for (rec, r) in records.iter().enumerate() {
        let tid = rec as u64 + 1;
        for s in obs.trace.spans().iter().filter(|s| s.tid == tid) {
            assert!(s.start_s >= r.arrival_s - EPS, "req {} span {} starts before arrival", r.id, s.name);
            if let Some(c) = r.completion_s {
                assert!(s.end_s <= c + EPS, "req {} span {} outlives completion", r.id, s.name);
            }
        }
        if let Some(f) = r.first_token_s {
            let inst = obs
                .trace
                .instants()
                .iter()
                .find(|i| i.tid == tid && i.name == "first_token")
                .unwrap_or_else(|| panic!("req {} got a first token but no instant", r.id));
            assert!((inst.t_s - f).abs() < EPS);
        }
    }
    // No span lost: the recorder never hit its (generous) cap.
    assert_eq!(obs.trace.dropped(), 0);
}

#[test]
fn serve_span_outcomes_and_counters_match_the_aggregate() {
    // The conservation anchor: spans closed with outcome=completed /
    // rejected and the monotonic counters must agree exactly with the
    // ServeOutcome the same run aggregates.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let t = trace(700.0, 3.0, 2026);
    let cfg = ServeConfig::default();
    let (o, _, obs) = simulate_observed(
        &sys,
        &ds,
        &t,
        &cfg,
        3.0,
        "poisson",
        700.0,
        &kernels,
        &stages,
        ObsConfig::default(),
    );
    let outcome_count = |which: &str| obs.trace.spans().iter().filter(|s| arg(s, "outcome") == Some(which)).count();
    assert_eq!(outcome_count("completed"), o.completed, "completed spans vs aggregate");
    assert_eq!(outcome_count("rejected"), o.rejected, "rejected spans vs aggregate");
    // In-flight + queued work at the horizon is exactly what close_open
    // marked unfinished (preempted-and-requeued lanes land here too).
    assert_eq!(outcome_count("unfinished"), o.in_flight + o.queued, "unfinished spans vs backlog");
    assert_eq!(obs.counters.get("completed"), o.completed as u64);
    assert_eq!(obs.counters.get("rejected"), o.rejected as u64);
    assert_eq!(obs.counters.get("arrivals"), o.arrived as u64);
    assert_eq!(obs.counters.get("preempted"), o.preemptions);
    assert_eq!(obs.counters.get("waves"), o.ticks);
    assert_eq!(
        obs.counters.get("first_tokens"),
        obs.trace.instants().iter().filter(|i| i.name == "first_token").count() as u64
    );
    // Gauges: sample times advance monotonically, fractions stay in [0, 1].
    for w in obs.series.rows().windows(2) {
        assert!(w[1].t_s >= w[0].t_s);
    }
    for row in obs.series.rows() {
        assert!((0.0..=1.0).contains(&row.prefix_hit_rate));
        assert!(row.kv_frac >= 0.0);
    }
}

#[test]
fn cluster_handoffs_follow_prefill_and_bundle_conserves() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let t = trace(300.0, 3.0, 5);
    let ccfg = ClusterConfig::disaggregated(1, 1, &ds);
    let (o, _, bundle) = simulate_cluster_observed(
        &sys,
        &ds,
        &t,
        &ccfg,
        3.0,
        300.0,
        &kernels,
        &stages,
        Some(ObsConfig::default()),
    );
    let bundle = bundle.expect("a sink was requested");
    // pid layout: entry pool, decode pool, then the fleet lane.
    assert_eq!(bundle.traces.len(), 3);
    assert_eq!(bundle.traces[0].process_name(), "prefill-0");
    assert_eq!(bundle.traces[1].process_name(), "decode-0");
    assert_eq!(bundle.traces[2].process_name(), "fleet");
    for r in &bundle.traces {
        assert_well_nested(r);
    }
    let fleet = &bundle.traces[2];
    let handoffs: Vec<&Span> = fleet.spans().iter().filter(|s| s.name == "handoff").collect();
    assert!(o.migrated > 0, "disaggregated run must migrate KV");
    assert_eq!(handoffs.len(), o.migrated, "one handoff span per migration");
    // Causality: every KV handoff starts at/after the end of a finished
    // prefill span for the same request on the entry pool.
    for h in &handoffs {
        let req = arg(h, "req").expect("handoff spans carry the request id");
        let prefill_done = bundle.traces[0]
            .spans()
            .iter()
            .any(|s| s.name == "prefill" && arg(s, "req") == Some(req) && s.end_s <= h.start_s + EPS);
        assert!(prefill_done, "handoff for req {req} starts before its prefill ended");
        assert!(arg(h, "bytes").is_some() && arg(h, "link_wait_s").is_some());
    }
    // Router telemetry: one route instant per processed arrival, spill
    // count mirrored into the counters.
    let routes = fleet.instants().iter().filter(|i| i.name == "route").count();
    assert_eq!(routes as u64, bundle.counters.get("routed"));
    assert!(bundle.counters.get("routed") > 0);
    assert_eq!(bundle.counters.get("handoffs"), o.migrated as u64);
    assert_eq!(bundle.counters.get("migrated"), o.migrated as u64);

    // Conservation on a colocated fleet, where entry completions ARE the
    // end-to-end completions: completed/rejected spans across every
    // instance recorder match the ClusterOutcome exactly.
    let ccfg = ClusterConfig::colocated(2, &ds);
    let (o, _, bundle) = simulate_cluster_observed(
        &sys,
        &ds,
        &t,
        &ccfg,
        3.0,
        300.0,
        &kernels,
        &stages,
        Some(ObsConfig::default()),
    );
    let bundle = bundle.expect("a sink was requested");
    let count = |which: &str| {
        bundle
            .traces
            .iter()
            .flat_map(|r| r.spans())
            .filter(|s| arg(s, "outcome") == Some(which))
            .count()
    };
    assert!(o.conserves_requests());
    assert_eq!(count("completed"), o.completed);
    assert_eq!(count("rejected"), o.rejected);
    assert_eq!(bundle.counters.get("completed"), o.completed as u64);
}

#[test]
fn link_busy_fraction_is_the_exact_interval_integral() {
    // The exact `SharedLink::busy_fraction` anchor: the reported link
    // telemetry must equal the time-in-window integral of per-migration
    // occupancy, reconstructed independently from the handoff spans
    // (span start = prefill completion; occupancy = [start + queue wait,
    // + serialization) clamped to the horizon). A single slow flow makes
    // the reconstruction see real queueing and horizon-clipped transfers.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let horizon = 3.0;
    let mut ccfg = ClusterConfig::disaggregated(1, 1, &ds);
    ccfg.transfer.parallel_flows = 1;
    ccfg.transfer.link_bandwidth_bytes_per_s = 2.0e9;
    let t = trace(400.0, horizon, 17);
    let (o, _, bundle) = simulate_cluster_observed(
        &sys,
        &ds,
        &t,
        &ccfg,
        horizon,
        400.0,
        &KernelCache::new(),
        &StageTimeCache::new(),
        Some(ObsConfig::default()),
    );
    let bundle = bundle.expect("a sink was requested");
    assert!(o.migrated > 0 && o.link_wait_s > 0.0, "the regime must queue the link");
    let fleet = bundle.traces.last().expect("fleet lane");
    let mut in_window = 0.0f64;
    let mut handoffs = 0usize;
    for s in fleet.spans().iter().filter(|s| s.name == "handoff") {
        handoffs += 1;
        let bytes: f64 = arg(s, "bytes").unwrap().parse().unwrap();
        let wait: f64 = arg(s, "link_wait_s").unwrap().parse().unwrap();
        let ser = bytes / ccfg.transfer.link_bandwidth_bytes_per_s;
        let start = s.start_s + wait;
        in_window += (start + ser).min(horizon).max(0.0) - start.clamp(0.0, horizon);
    }
    assert_eq!(handoffs, o.migrated, "one handoff span per migration");
    let expect = (in_window / (horizon * ccfg.transfer.parallel_flows as f64)).min(1.0);
    assert!(
        (o.link_busy_frac - expect).abs() < 1e-5,
        "busy fraction {} disagrees with the reconstructed integral {expect}",
        o.link_busy_frac
    );
    assert!(o.link_busy_frac > 0.0 && o.link_busy_frac <= 1.0);
}

#[test]
fn same_seed_runs_export_byte_identical_artifacts() {
    // The acceptance criterion: no wall clock, no map-order dependence —
    // two fresh same-seed runs render byte-identical artifacts, for both
    // the standalone engine and the disaggregated fleet.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let serve_run = || {
        let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
        let t = trace(500.0, 2.5, 77);
        let cfg = ServeConfig::default();
        let (_, _, obs) = simulate_observed(
            &sys,
            &ds,
            &t,
            &cfg,
            2.5,
            "poisson",
            500.0,
            &kernels,
            &stages,
            ObsConfig::default(),
        );
        let mut b = ObsBundle::new();
        b.push_engine(*obs);
        b.exports()
    };
    let (a, b) = (serve_run(), serve_run());
    assert_eq!(a.trace_json, b.trace_json, "serve trace must replay byte-identically");
    assert_eq!(a.series_csv, b.series_csv);
    assert_eq!(a.series_json, b.series_json);
    assert_eq!(a.metrics_text, b.metrics_text);
    assert!(a.trace_json.contains("\"traceEvents\":["));
    assert!(a.metrics_text.contains("flatattention_completed_total"));

    let cluster_run = || {
        let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
        let t = generate_trace(
            &TraceConfig::new(77, TrafficPattern::Poisson, 300.0, 2.5).with_prefixes(PrefixProfile::agentic()),
        );
        let ccfg = ClusterConfig::disaggregated(1, 2, &ds);
        let (_, _, bundle) = simulate_cluster_observed(
            &sys,
            &ds,
            &t,
            &ccfg,
            2.5,
            300.0,
            &kernels,
            &stages,
            Some(ObsConfig::default()),
        );
        bundle.expect("a sink was requested").exports()
    };
    let (a, b) = (cluster_run(), cluster_run());
    assert_eq!(a.trace_json, b.trace_json, "cluster trace must replay byte-identically");
    assert_eq!(a.series_csv, b.series_csv);
    assert_eq!(a.series_json, b.series_json);
    assert_eq!(a.metrics_text, b.metrics_text);
}

#[test]
fn attaching_a_sink_never_changes_the_simulation() {
    // Observability must be a pure observer: the instrumented run's outcome
    // and per-request records equal the plain run's bit for bit.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let t = trace(450.0, 3.0, 9);
    let cfg = ServeConfig::default();
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let (plain, plain_recs) = simulate(&sys, &ds, &t, &cfg, 3.0, "poisson", 450.0, &kernels, &stages);
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let (observed, observed_recs, _) = simulate_observed(
        &sys,
        &ds,
        &t,
        &cfg,
        3.0,
        "poisson",
        450.0,
        &kernels,
        &stages,
        ObsConfig::default(),
    );
    assert_eq!(plain, observed, "the sink changed the serve outcome");
    assert_eq!(plain_recs, observed_recs);

    let ccfg = ClusterConfig::disaggregated(1, 1, &ds);
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let (plain, plain_recs) = simulate_cluster(&sys, &ds, &t, &ccfg, 3.0, 450.0, &kernels, &stages);
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let (observed, observed_recs, bundle) = simulate_cluster_observed(
        &sys,
        &ds,
        &t,
        &ccfg,
        3.0,
        450.0,
        &kernels,
        &stages,
        Some(ObsConfig::default()),
    );
    assert!(bundle.is_some());
    assert_eq!(plain, observed, "the sink changed the cluster outcome");
    assert_eq!(plain_recs, observed_recs);
}

#[test]
fn span_cap_drops_are_accounted_in_every_export() {
    // A tiny cap forces drops; the count must surface in the trace header
    // and the Prometheus counters rather than vanish.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let (kernels, stages) = (KernelCache::new(), StageTimeCache::new());
    let t = trace(400.0, 2.0, 3);
    let cfg = ServeConfig::default();
    let tiny = ObsConfig { span_cap: 8, ..ObsConfig::default() };
    let (_, _, obs) = simulate_observed(&sys, &ds, &t, &cfg, 2.0, "poisson", 400.0, &kernels, &stages, tiny);
    assert!(obs.trace.dropped() > 0, "the tiny cap must actually drop events");
    let dropped = obs.trace.dropped();
    let mut b = ObsBundle::new();
    b.push_engine(*obs);
    let e = b.exports();
    assert!(e.trace_json.contains(&format!("\"dropped_events\":\"{dropped}\"")));
    assert!(e.metrics_text.contains(&format!("flatattention_trace_events_dropped_total {dropped}")));
}
