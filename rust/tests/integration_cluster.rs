//! Cluster-layer invariants: the interleaved-fleet equivalence anchor
//! (1 colocated instance == `serve::simulate`, byte-identical), the
//! sharded-engine bit-identity anchor (ANY shard count == the serial loop:
//! outcomes, records AND obs exports, on overdriven preempting/contended
//! fleets), fleet-wide request conservation across pools, fixed-seed
//! determinism of the `cluster_pools` experiment (the acceptance
//! criterion's byte-identical replay), the KV-transfer-bytes == latent-KV
//! layout identity for every migrated request, causal per-request
//! timelines through prefill → transfer (with link congestion) → decode,
//! and the fault-injection anchors: conservation under mid-run kills, the
//! requeued-work-completes-on-a-survivor guarantee, and shard bit-identity
//! with an active fault plan (outcome, records AND obs exports).
//!
//! Fabric anchors (the topology-aware KV fabric): the degenerate 1-switch
//! topology projects the historical pooled `SharedLink` fleet exactly,
//! hop-bytes/edge-ledger conservation holds under faults (restart weight
//! reloads and requeue re-ships bill into the SAME per-edge ledgers), and
//! shard bit-identity survives a contended torus with hop-aware decode
//! placement and an active kill + restart plan.

use flatattention::cluster::{
    simulate_cluster, simulate_cluster_faulted_observed, simulate_cluster_observed, ClusterConfig, Fabric,
    FaultPlan, FleetMode, RoutingPolicy, TopologySpec,
};
use flatattention::coordinator::experiments;
use flatattention::multichip::d2d::WaferSystem;
use flatattention::multichip::parallelism::KernelCache;
use flatattention::obs::ObsConfig;
use flatattention::serve::request::{generate_trace, LengthProfile, PrefixProfile, TraceConfig, TrafficPattern};
use flatattention::serve::scheduler::AdmissionPolicy;
use flatattention::serve::sim::{simulate, StageTimeCache};
use flatattention::workload::deepseek::DeepSeekConfig;

fn trace(rate: f64, horizon: f64, seed: u64) -> Vec<flatattention::serve::request::Request> {
    generate_trace(&TraceConfig::new(seed, TrafficPattern::Poisson, rate, horizon))
}

#[test]
fn interleaved_single_instance_fleet_equals_serve_simulate_byte_identically() {
    // The tentpole's equivalence anchor: a 1-instance colocated fleet on
    // the interleaved event clock must reproduce the standalone serving
    // simulator's ServeOutcome byte-identically — every record timestamp,
    // every percentile, every counter. The fleet layer may add NOTHING an
    // isolated instance would notice. Exercised on shared-prefix traffic
    // too, so the prefix-affinity router and prefix-cache paths are in
    // play, and across two seeds.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    for (seed, prefixes) in [(3u64, false), (71u64, true)] {
        let mut tc = TraceConfig::new(seed, TrafficPattern::Poisson, 150.0, 4.0);
        if prefixes {
            tc = tc.with_prefixes(PrefixProfile::agentic());
        }
        let t = generate_trace(&tc);
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let ccfg = ClusterConfig::colocated(1, &ds);
        let (co, crecs) = simulate_cluster(&sys, &ds, &t, &ccfg, 4.0, 150.0, &kernels, &stages);
        // Role label matches the fleet's per-instance pattern label so the
        // two ServeOutcomes compare structurally field-for-field.
        let (so, srecs) = simulate(&sys, &ds, &t, &ccfg.serve, 4.0, "colocated", 0.0, &kernels, &stages);
        assert_eq!(crecs.len(), srecs.len());
        for (c, s) in crecs.iter().zip(&srecs) {
            assert_eq!(c.id, s.id, "seed {seed}");
            assert_eq!(c.arrival_s, s.arrival_s);
            assert_eq!(c.first_token_s, s.first_token_s, "seed {seed} id {}", c.id);
            assert_eq!(c.completion_s, s.completion_s, "seed {seed} id {}", c.id);
            assert_eq!(c.prefill_instance, 0);
            assert_eq!(c.decode_instance, 0);
            assert_eq!(c.transfer_bytes, 0);
        }
        // The fleet's single InstanceSummary is a projection of exactly the
        // serve outcome …
        assert_eq!(co.instances.len(), 1);
        let inst = &co.instances[0];
        assert_eq!(inst.routed, so.offered);
        assert_eq!(inst.completed, so.completed);
        assert_eq!(inst.rejected, so.rejected);
        assert_eq!(inst.backlog, so.in_flight + so.queued);
        assert_eq!(inst.preemptions, so.preemptions);
        assert_eq!(inst.prefix_hit_tokens, so.prefix_hit_tokens);
        assert_eq!(inst.tokens_per_s, so.system_tokens_per_s);
        assert_eq!(inst.peak_kv_occupancy, so.peak_kv_occupancy);
        // … and the fleet aggregates agree bit-for-bit (f64 equality — no
        // tolerance).
        assert_eq!(co.arrived, so.arrived);
        assert_eq!(co.completed, so.completed);
        assert_eq!(co.rejected, so.rejected);
        assert_eq!(co.in_flight, so.in_flight + so.queued);
        assert_eq!(co.completed_within_slo, so.completed_within_slo);
        assert_eq!(co.ttft_ms, so.ttft_ms);
        assert_eq!(co.tpot_ms, so.tpot_ms);
        assert_eq!(co.fleet_tokens_per_s, so.system_tokens_per_s);
        assert_eq!(co.goodput_rps, so.goodput_rps);
        assert_eq!(co.kv_over_capacity, so.kv_over_capacity);
        assert_eq!(co.preemptions, so.preemptions);
        assert_eq!(co.migrated, 0);
        assert_eq!(co.in_transfer, 0);
        assert_eq!(co.link_busy_frac, 0.0);
    }
}

#[test]
fn cluster_pools_experiment_replays_byte_identically() {
    // Two identical invocations of the `cluster_pools` experiment (what
    // `flatattention cluster` runs) must render the identical report —
    // fleet tokens/s, TTFT/TPOT percentiles, goodput, transfer overhead and
    // the crossover notes included.
    let a = experiments::run("cluster_pools", true).expect("cluster_pools").render();
    let b = experiments::run("cluster_pools", true).expect("cluster_pools").render();
    assert_eq!(a, b, "fixed-seed cluster_pools must replay byte-identically");
    // The report carries every headline the acceptance criteria name.
    for needle in ["tok/s", "TTFT p50", "p99 (ms)", "goodput", "transfer", "migrated", "colocated-4", "disagg-2p2d"] {
        assert!(a.contains(needle), "report lost the '{needle}' column/row:\n{a}");
    }
}

#[test]
fn request_conservation_across_pools_and_modes() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let kernels = KernelCache::new();
    let stages = StageTimeCache::new();
    let t = trace(600.0, 4.0, 23);
    for mode in [
        FleetMode::Colocated { instances: 2 },
        FleetMode::Disaggregated { prefill: 1, decode: 1 },
        FleetMode::Disaggregated { prefill: 2, decode: 2 },
    ] {
        let ccfg = ClusterConfig { mode, ..ClusterConfig::colocated(2, &ds) };
        let (o, recs) = simulate_cluster(&sys, &ds, &t, &ccfg, 4.0, 600.0, &kernels, &stages);
        // Fleet-wide: admitted = completed + rejected + in-flight at horizon.
        assert!(o.conserves_requests(), "{mode:?}: {o:?}");
        assert!(o.arrived <= o.offered);
        assert!(o.completed > 0, "{mode:?}: nothing completed");
        assert!(!o.kv_over_capacity, "{mode:?} overflowed KV");
        // The in-flight split is itself consistent.
        let backlog: usize = o.instances.iter().map(|i| i.backlog).sum();
        assert_eq!(o.in_flight, backlog + o.in_transfer, "{mode:?}");
        // Record-level: completions are unique outcomes of arrived requests.
        let completed = recs.iter().filter(|r| r.completion_s.is_some()).count();
        assert_eq!(completed, o.completed, "{mode:?}");
    }
}

#[test]
fn kv_transfer_bytes_equal_latent_layout_for_every_migration() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let ccfg = ClusterConfig::disaggregated(1, 1, &ds);
    let t = trace(300.0, 4.0, 31);
    let (o, recs) = simulate_cluster(
        &sys,
        &ds,
        &t,
        &ccfg,
        4.0,
        300.0,
        &KernelCache::new(),
        &StageTimeCache::new(),
    );
    assert!(o.migrated > 0, "disaggregated run must migrate KV");
    // Independent latent-layout arithmetic (not via KvTransferModel): the
    // MLA cache ships (d_c + d_rope) × 1 B (FP8) per token per layer.
    let layout_bytes = (ds.kv_lora_rank + ds.qk_rope_dim) as u64 * ds.layers as u64;
    let mut migrated = 0usize;
    let mut total = 0u64;
    for r in &recs {
        if r.decode_instance != u32::MAX {
            migrated += 1;
            assert_eq!(
                r.transfer_bytes,
                r.prompt_tokens as u64 * layout_bytes,
                "request {} shipped {} bytes, latent layout says {}",
                r.id,
                r.transfer_bytes,
                r.prompt_tokens as u64 * layout_bytes
            );
            total += r.transfer_bytes;
        } else {
            assert_eq!(r.transfer_bytes, 0);
        }
    }
    assert_eq!(migrated, o.migrated);
    assert_eq!(total, o.kv_transfer_bytes);
}

#[test]
fn migrated_timelines_are_causal_and_pay_the_handoff() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let ccfg = ClusterConfig::disaggregated(1, 1, &ds);
    let t = trace(150.0, 4.0, 41);
    let (o, recs) = simulate_cluster(
        &sys,
        &ds,
        &t,
        &ccfg,
        4.0,
        150.0,
        &KernelCache::new(),
        &StageTimeCache::new(),
    );
    assert!(o.completed > 0);
    for r in &recs {
        if let Some(f) = r.first_token_s {
            // The user-visible first token includes the exposed handoff
            // delay, so TTFT is strictly above the transfer time.
            assert!(r.transfer_s > 0.0, "migrated request without transfer: {r:?}");
            assert!(f >= r.arrival_s + r.transfer_s, "first token beat the handoff: {r:?}");
        }
        if let (Some(f), Some(c)) = (r.first_token_s, r.completion_s) {
            assert!(c >= f, "completion before first token: {r:?}");
            assert!(r.tpot_ms().unwrap_or(0.0) >= 0.0);
        }
    }
    assert!(o.kv_transfer_exposed_s > 0.0);
}

#[test]
fn sharded_engine_is_bit_identical_to_serial_at_every_shard_count() {
    // THE tentpole anchor: the sharded conservative-lookahead engine must
    // reproduce the serial loop bit for bit at every shard count — same
    // ClusterOutcome (modulo the self-describing `shards` stamp), same
    // per-request records, and byte-identical observability exports
    // (Chrome trace, gauge series, Prometheus counters). Exercised on two
    // deliberately nasty regimes:
    //
    //  - an overdriven memory-starved colocated fleet (on-demand admission
    //    on decode-heavy traffic ⇒ preemptions) under live prefix-affinity
    //    routing (the epoch-start snapshot path);
    //  - a disaggregated fleet on a one-flow starved link (handoff
    //    contention ⇒ link queueing) with live least-queue-depth decode
    //    routing (the decode-pool snapshot path).
    let ds = DeepSeekConfig::v3_671b();

    // Regime 1: preemptions. 10 GiB HBM/chip + decode-heavy traffic is the
    // known pressure recipe (see integration_serve); two instances at
    // 5000 rps keep each one past the single-instance preemption point.
    let mut starved = WaferSystem::paper();
    starved.chip.hbm.capacity_gib_per_stack = 10;
    let mut tc = TraceConfig::new(5, TrafficPattern::Poisson, 5000.0, 4.0).with_prefixes(PrefixProfile::agentic());
    tc.lengths = LengthProfile::decode_heavy();
    let overdriven = generate_trace(&tc);
    let mut colocated = ClusterConfig::colocated(2, &ds);
    colocated.serve.scheduler.policy = AdmissionPolicy::OnDemandPreempt;

    // Regime 2: handoff contention. One slow flow queues concurrent
    // migrations (the link_congestion recipe), live decode routing.
    let contended = generate_trace(
        &TraceConfig::new(17, TrafficPattern::Poisson, 400.0, 3.0).with_prefixes(PrefixProfile::agentic()),
    );
    let mut disagg = ClusterConfig::disaggregated(1, 2, &ds);
    disagg.decode_routing = RoutingPolicy::LeastQueueDepth;
    disagg.transfer.parallel_flows = 1;
    disagg.transfer.link_bandwidth_bytes_per_s = 2.0e9;

    let cases = [
        (WaferSystem::paper(), disagg, &contended, 400.0, 3.0),
        (starved, colocated, &overdriven, 5000.0, 4.0),
    ];
    for (sys, base, trace, rate, horizon) in cases {
        // Fresh caches per run: the kernel/stage hit/miss counters are
        // process-cumulative and land in the exported metrics text, so a
        // byte comparison needs every run to start from the same cache
        // state. (Cache *contents* never change results.)
        let run = |shards: u32| {
            let cfg = ClusterConfig { shards, ..base };
            let (o, recs, bundle) = simulate_cluster_observed(
                &sys,
                &ds,
                trace,
                &cfg,
                horizon,
                rate,
                &KernelCache::new(),
                &StageTimeCache::new(),
                Some(ObsConfig::default()),
            );
            (o, recs, bundle.expect("obs requested").exports())
        };
        let (mut serial, serial_recs, serial_exp) = run(1);
        assert!(serial.conserves_requests());
        match base.mode {
            FleetMode::Disaggregated { .. } => {
                assert!(serial.migrated > 0, "contention regime must migrate KV");
                assert!(serial.link_wait_s > 0.0, "contention regime must queue handoffs");
            }
            FleetMode::Colocated { .. } => {
                assert!(serial.preemptions > 0, "pressure regime must preempt");
            }
        }
        serial.shards = 1;
        for shards in [2u32, 4, 7] {
            let (mut o, recs, exp) = run(shards);
            assert_eq!(o.shards, shards, "outcome must state the shard count used");
            // Every other field must agree bit for bit — normalize the
            // stamp, then compare structurally (f64 equality, no tolerance).
            o.shards = 1;
            assert_eq!(o, serial, "{} fleet: {shards} shards diverged from serial", base.mode.label());
            assert_eq!(recs, serial_recs, "{} fleet: {shards} shards record divergence", base.mode.label());
            assert_eq!(exp.trace_json, serial_exp.trace_json, "{shards} shards: trace export diverged");
            assert_eq!(exp.series_csv, serial_exp.series_csv, "{shards} shards: series export diverged");
            assert_eq!(exp.series_json, serial_exp.series_json, "{shards} shards: series JSON diverged");
            assert_eq!(exp.metrics_text, serial_exp.metrics_text, "{shards} shards: metrics export diverged");
        }
    }
}

#[test]
fn faulted_fleet_conserves_and_requeues_across_pools() {
    // Fault-injection conservation anchor: killing an instance mid-run
    // extracts its work and re-enters it through the entry router — the
    // extended identity `arrived == completed + rejected + in_flight +
    // extracted_from_decode` must hold in every fleet mode, every requeue
    // must land in exactly one record, and requeued timelines stay causal.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let kernels = KernelCache::new();
    let stages = StageTimeCache::new();
    let t = trace(600.0, 4.0, 23);
    for (mode, victim) in [
        (FleetMode::Colocated { instances: 2 }, 0usize),
        (FleetMode::Disaggregated { prefill: 2, decode: 2 }, 3),
    ] {
        let ccfg = ClusterConfig { mode, ..ClusterConfig::colocated(2, &ds) };
        let plan = FaultPlan::none().kill(victim, 2.0);
        let (o, recs, _) = simulate_cluster_faulted_observed(
            &sys, &ds, &t, &ccfg, &plan, 4.0, 600.0, &kernels, &stages, None,
        );
        assert_eq!(o.faults, 1, "{mode:?}");
        assert!(o.conserves_requests(), "{mode:?}: {o:?}");
        assert!(o.requeued > 0, "{mode:?}: a loaded instance died with no stranded work");
        assert_eq!(recs.iter().map(|r| r.requeues as usize).sum::<usize>(), o.requeued, "{mode:?}");
        let completed = recs.iter().filter(|r| r.completion_s.is_some()).count();
        assert_eq!(completed, o.completed, "{mode:?}");
        for r in &recs {
            if let (Some(f), Some(c)) = (r.first_token_s, r.completion_s) {
                assert!(f >= r.arrival_s && c >= f, "{mode:?} causality after requeue: {r:?}");
            }
        }
    }
}

#[test]
fn requeued_requests_complete_on_a_survivor() {
    // A decode-instance kill re-homes its victims: they re-enter the entry
    // pool, re-prefill from scratch, re-ship their KV to the surviving
    // decode instance and stream to completion there.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let ccfg = ClusterConfig::disaggregated(1, 2, &ds);
    let t = trace(150.0, 4.0, 41);
    // Gid 1 = decode instance 0 (the entry pool is gid 0 alone).
    let plan = FaultPlan::none().kill(1, 1.5);
    let (o, recs, _) = simulate_cluster_faulted_observed(
        &sys,
        &ds,
        &t,
        &ccfg,
        &plan,
        4.0,
        150.0,
        &KernelCache::new(),
        &StageTimeCache::new(),
        None,
    );
    assert!(o.conserves_requests(), "{o:?}");
    assert!(o.extracted_from_decode > 0, "the dead decode pool must strand landed work");
    assert!(o.requeued > 0);
    assert!(o.kv_lost_bytes > 0);
    let survivors: Vec<_> = recs.iter().filter(|r| r.requeues > 0 && r.completion_s.is_some()).collect();
    assert!(!survivors.is_empty(), "light load must finish its requeued work before the horizon");
    for r in &survivors {
        assert_eq!(r.decode_instance, 1, "completed victim must sit on the surviving decode instance: {r:?}");
        assert!(r.transfer_s > 0.0, "a re-migrated victim must have paid the handoff: {r:?}");
    }
}

#[test]
fn faulted_sharded_engine_is_bit_identical_with_obs_exports() {
    // The PR's golden anchor: a fault plan mixing a prefill drain with a
    // mid-horizon decode kill + restart replays byte-identically at every
    // shard count — same outcome, same per-request records, and the same
    // four observability exports, fault instants and counters included.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let t = generate_trace(
        &TraceConfig::new(17, TrafficPattern::Poisson, 400.0, 3.0).with_prefixes(PrefixProfile::agentic()),
    );
    let base = ClusterConfig::disaggregated(2, 2, &ds);
    let plan = FaultPlan::none().drain(0, 0.8).kill(3, 1.5).with_restart(0.3);
    let run = |shards: u32| {
        let cfg = ClusterConfig { shards, ..base };
        let (o, recs, bundle) = simulate_cluster_faulted_observed(
            &sys,
            &ds,
            &t,
            &cfg,
            &plan,
            3.0,
            400.0,
            &KernelCache::new(),
            &StageTimeCache::new(),
            Some(ObsConfig::default()),
        );
        (o, recs, bundle.expect("obs requested").exports())
    };
    let (mut serial, serial_recs, serial_exp) = run(1);
    assert!(serial.conserves_requests(), "{serial:?}");
    assert_eq!(serial.faults, 2);
    assert!(serial.requeued > 0, "the decode kill must strand work");
    assert!(serial.kv_lost_bytes > 0);
    assert!(serial_exp.metrics_text.contains("flatattention_faults_total"));
    assert!(serial_exp.metrics_text.contains("flatattention_requests_requeued_total"));
    assert!(serial_exp.metrics_text.contains("flatattention_kv_lost_bytes_total"));
    serial.shards = 1;
    for shards in [2u32, 4] {
        let (mut o, recs, exp) = run(shards);
        assert_eq!(o.shards, shards);
        o.shards = 1;
        assert_eq!(o, serial, "{shards} shards diverged under the fault plan");
        assert_eq!(recs, serial_recs, "{shards} shards: record divergence under faults");
        assert_eq!(exp.trace_json, serial_exp.trace_json, "{shards} shards: trace export diverged");
        assert_eq!(exp.series_csv, serial_exp.series_csv, "{shards} shards: series export diverged");
        assert_eq!(exp.series_json, serial_exp.series_json, "{shards} shards: series JSON diverged");
        assert_eq!(exp.metrics_text, serial_exp.metrics_text, "{shards} shards: metrics export diverged");
    }
}

#[test]
fn fleet_scales_served_load() {
    // A 2-instance colocated fleet must outserve a single instance on the
    // identical overdriven trace (more aggregate prefill + decode capacity).
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let kernels = KernelCache::new();
    let stages = StageTimeCache::new();
    // 4000 rps saturates one wafer instance (the serve-load golden anchor
    // pins its p99 TPOT above the SLO there), so a second instance must
    // show up directly in fleet throughput.
    let t = trace(4000.0, 3.0, 47);
    let run = |n: u32| {
        let ccfg = ClusterConfig::colocated(n, &ds);
        simulate_cluster(&sys, &ds, &t, &ccfg, 3.0, 4000.0, &kernels, &stages).0
    };
    let one = run(1);
    let two = run(2);
    assert!(one.conserves_requests() && two.conserves_requests());
    assert!(
        two.fleet_tokens_per_s > 1.2 * one.fleet_tokens_per_s,
        "2 instances must outserve 1: {} vs {}",
        two.fleet_tokens_per_s,
        one.fleet_tokens_per_s
    );
    assert!(two.completed >= one.completed);
}

#[test]
fn degenerate_topology_preserves_the_pooled_link_fleet() {
    // The degenerate 1-switch topology IS the historical pooled
    // `SharedLink`: it must stay the `ClusterConfig` default, bill exactly
    // one hop per migration into a single ledger entry, and that entry
    // must integrate to exactly Σ transfer bytes / bandwidth — the pooled
    // link's serialization total. (The switch-level field identity against
    // a raw `SharedLink` replay is pinned in `cluster::fabric`'s unit
    // tests; this is the fleet-level projection of the same anchor.)
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let t = generate_trace(
        &TraceConfig::new(53, TrafficPattern::Poisson, 300.0, 3.0).with_prefixes(PrefixProfile::agentic()),
    );
    let base = ClusterConfig::disaggregated(2, 2, &ds);
    assert_eq!(base.topology, TopologySpec::Degenerate, "the pooled switch must stay the default");
    let (o, recs) =
        simulate_cluster(&sys, &ds, &t, &base, 3.0, 300.0, &KernelCache::new(), &StageTimeCache::new());
    assert!(o.conserves_requests() && o.migrated > 0, "{o:?}");
    assert_eq!(o.fabric_hops, o.migrated as u64, "pooled switch: one traversal per migration");
    assert_eq!(o.edge_busy_s.len(), 1, "pooled switch: one ledger, not per-edge entries");
    for r in &recs {
        assert_eq!(r.transfer_hop_bytes, r.transfer_bytes, "{r:?}");
    }
    let bytes: u64 = recs.iter().map(|r| r.transfer_bytes).sum();
    let expect = bytes as f64 / base.transfer.link_bandwidth_bytes_per_s;
    assert!(
        (o.edge_busy_s[0] - expect).abs() <= 1e-9 * expect.max(1.0),
        "pooled ledger {} s vs Σ bytes / bandwidth {expect} s",
        o.edge_busy_s[0]
    );
}

#[test]
fn fabric_conservation_holds_under_faults_and_reloads() {
    // Satellite anchor: restart cold-start weight reloads and requeue KV
    // re-ships route over the SAME per-edge fabric ledgers as the regular
    // handoffs — no phantom pooled link. On a contended torus with a
    // decode kill + restart, the summed per-edge busy ledger must equal
    // (Σ per-request hop-bytes + reload bytes × reload hops) / bandwidth
    // exactly.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let mut ccfg = ClusterConfig::disaggregated(2, 2, &ds);
    ccfg.topology = TopologySpec::Torus;
    ccfg.decode_routing = RoutingPolicy::TopoAware;
    let t = generate_trace(
        &TraceConfig::new(29, TrafficPattern::Poisson, 200.0, 4.0).with_prefixes(PrefixProfile::agentic()),
    );
    // Gid 3 = decode instance 1; the kill's replacement cold-starts 0.3 s
    // later, reloading the full EP×PP weight footprint over the fabric.
    let plan = FaultPlan::none().kill(3, 1.5).with_restart(0.3);
    let (o, recs, _) = simulate_cluster_faulted_observed(
        &sys,
        &ds,
        &t,
        &ccfg,
        &plan,
        4.0,
        200.0,
        &KernelCache::new(),
        &StageTimeCache::new(),
        None,
    );
    assert!(o.conserves_requests(), "{o:?}");
    assert!(o.migrated > 0 && o.requeued > 0, "{o:?}");
    assert!(o.link_wait_s > 0.0, "the torus boundary must queue handoffs: {o:?}");
    assert!(o.edge_busy_s.len() > 1, "a torus must expose per-edge ledgers, not one pooled entry");
    // A requeued victim that finished re-shipped its KV — both trips
    // accumulate in its record (and therefore in the ledger equality).
    assert!(
        recs.iter().any(|r| r.requeues > 0 && r.completion_s.is_some() && r.transfer_s > 0.0),
        "no requeued request re-migrated inside the horizon"
    );
    let bw = ccfg.transfer.link_bandwidth_bytes_per_s;
    let hop_bytes: u64 = recs.iter().map(|r| r.transfer_hop_bytes).sum();
    let ledger: f64 = o.edge_busy_s.iter().sum();
    assert!(
        ledger > hop_bytes as f64 / bw,
        "the weight reload must leave per-edge occupancy beyond the handoffs: {ledger}"
    );
    // Reload route: instance 0 is the fleet's checkpoint host; gid 3 sits
    // two dimension-ordered hops away on the 2×2 torus.
    let kvm = flatattention::serve::kv::KvCacheModel::new(&sys, &ds, ccfg.serve.plan, ccfg.serve.dtype);
    let reload_bytes = kvm.weight_bytes_per_chip * ccfg.serve.plan.ep as u64 * ccfg.serve.plan.pp as u64;
    let reload_hops = Fabric::new(TopologySpec::Torus, 4, &ccfg.transfer).hops(0, 3);
    assert_eq!(reload_hops, 2);
    let expect = (hop_bytes as f64 + (reload_bytes * reload_hops) as f64) / bw;
    assert!(
        (ledger - expect).abs() <= 1e-9 * expect.max(1.0),
        "per-edge ledger {ledger} s vs billed handoffs + reload {expect} s"
    );
}

#[test]
fn fabric_sharded_engine_is_bit_identical_on_contended_torus_with_faults() {
    // Acceptance anchor: shard-{1,2,4} outcomes, records and all four obs
    // exports stay byte-identical with the routed fabric active — per-edge
    // queueing on a starved torus, hop-aware decode placement, a mid-run
    // decode kill + restart (weight reload over the fabric) and requeue
    // re-ships all in play at once.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let t = generate_trace(
        &TraceConfig::new(31, TrafficPattern::Poisson, 400.0, 3.0).with_prefixes(PrefixProfile::agentic()),
    );
    let mut base = ClusterConfig::disaggregated(2, 2, &ds);
    base.topology = TopologySpec::Torus;
    base.decode_routing = RoutingPolicy::TopoAware;
    base.transfer.parallel_flows = 1;
    base.transfer.link_bandwidth_bytes_per_s = 4.0e9;
    let plan = FaultPlan::none().kill(3, 1.5).with_restart(0.3);
    let run = |shards: u32| {
        let cfg = ClusterConfig { shards, ..base };
        let (o, recs, bundle) = simulate_cluster_faulted_observed(
            &sys,
            &ds,
            &t,
            &cfg,
            &plan,
            3.0,
            400.0,
            &KernelCache::new(),
            &StageTimeCache::new(),
            Some(ObsConfig::default()),
        );
        (o, recs, bundle.expect("obs requested").exports())
    };
    let (mut serial, serial_recs, serial_exp) = run(1);
    assert!(serial.conserves_requests(), "{serial:?}");
    assert!(serial.migrated > 0 && serial.link_wait_s > 0.0, "the torus must contend: {serial:?}");
    assert!(serial.requeued > 0, "the decode kill must strand work");
    assert!(serial_exp.metrics_text.contains("flatattention_fabric_hops_total"));
    assert!(serial_exp.series_csv.contains("edge_busy_frac"));
    serial.shards = 1;
    for shards in [2u32, 4] {
        let (mut o, recs, exp) = run(shards);
        assert_eq!(o.shards, shards);
        o.shards = 1;
        assert_eq!(o, serial, "{shards} shards diverged on the contended torus under faults");
        assert_eq!(recs, serial_recs, "{shards} shards: record divergence");
        assert_eq!(exp.trace_json, serial_exp.trace_json, "{shards} shards: trace export diverged");
        assert_eq!(exp.series_csv, serial_exp.series_csv, "{shards} shards: series export diverged");
        assert_eq!(exp.series_json, serial_exp.series_json, "{shards} shards: series JSON diverged");
        assert_eq!(exp.metrics_text, serial_exp.metrics_text, "{shards} shards: metrics export diverged");
    }
}
