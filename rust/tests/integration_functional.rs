//! Functional + PJRT integration: the Rust dataflow executors against the
//! AOT-compiled JAX/Pallas artifacts (requires `make artifacts`; tests
//! self-skip with a warning when artifacts are absent so `cargo test` works
//! on a fresh checkout).

use flatattention::dataflow::FlatTiling;
use flatattention::exec::functional;
use flatattention::exec::tensor::Mat;
use flatattention::runtime::artifacts::{artifact_path, artifacts_ready, Artifact};
use flatattention::runtime::pjrt::HloExecutable;
use flatattention::util::SplitMix64;

fn ready_or_skip(test: &str) -> bool {
    if artifacts_ready() {
        true
    } else {
        eprintln!("SKIP {test}: artifacts missing — run `make artifacts`");
        false
    }
}

#[test]
fn mha_prefill_artifact_matches_flat_executor() {
    if !ready_or_skip("mha_prefill") {
        return;
    }
    let exe = HloExecutable::load(artifact_path(Artifact::MhaPrefill).unwrap()).unwrap();
    let mut rng = SplitMix64::new(1);
    let (sq, d) = (256usize, 64usize);
    let q = Mat::random(sq, d, &mut rng);
    let k = Mat::random(sq, d, &mut rng);
    let v = Mat::random(sq, d, &mut rng);
    let golden = exe.run_f32(&[&q, &k, &v], sq, d).unwrap();
    for tiling in [
        FlatTiling { gx: 1, gy: 1, slice_r: 64, slice_c: 64 },
        FlatTiling { gx: 4, gy: 4, slice_r: 16, slice_c: 16 },
        FlatTiling { gx: 8, gy: 2, slice_r: 32, slice_c: 8 },
    ] {
        let flat = functional::flat_attention(&q, &k, &v, &tiling);
        let err = flat.max_abs_diff(&golden);
        assert!(err < 5e-3, "tiling {tiling:?}: err {err}");
    }
    // Flash executor agrees too.
    let flash = functional::flash_attention(&q, &k, &v, 32, 32);
    assert!(flash.max_abs_diff(&golden) < 5e-3);
}

#[test]
fn kernel_and_reference_artifacts_agree() {
    if !ready_or_skip("kernel_vs_reference") {
        return;
    }
    // Two independently lowered graphs (Pallas kernel vs dense jnp) must
    // produce the same numbers through the PJRT runtime.
    let kern = HloExecutable::load(artifact_path(Artifact::MhaPrefill).unwrap()).unwrap();
    let dense = HloExecutable::load(artifact_path(Artifact::MhaReference).unwrap()).unwrap();
    let mut rng = SplitMix64::new(2);
    let (sq, d) = (256usize, 64usize);
    let q = Mat::random(sq, d, &mut rng);
    let k = Mat::random(sq, d, &mut rng);
    let v = Mat::random(sq, d, &mut rng);
    let a = kern.run_f32(&[&q, &k, &v], sq, d).unwrap();
    let b = dense.run_f32(&[&q, &k, &v], sq, d).unwrap();
    assert!(a.max_abs_diff(&b) < 1e-4, "kernel vs dense: {}", a.max_abs_diff(&b));
}

#[test]
fn gqa_decode_artifact_matches_executor() {
    if !ready_or_skip("gqa_decode") {
        return;
    }
    let exe = HloExecutable::load(artifact_path(Artifact::GqaDecode).unwrap()).unwrap();
    let mut rng = SplitMix64::new(3);
    // Shapes from python/compile/model.py: rows = 8·2, kv = 256, d = 64.
    let (rows, kv, d) = (16usize, 256usize, 64usize);
    let q = Mat::random(rows, d, &mut rng);
    let k = Mat::random(kv, d, &mut rng);
    let v = Mat::random(kv, d, &mut rng);
    let golden = exe.run_f32(&[&q, &k, &v], rows, d).unwrap();
    // Single-row group, the §III-D decode mapping.
    let t = FlatTiling { gx: 8, gy: 1, slice_r: rows as u32, slice_c: 32 };
    let flat = functional::flat_attention(&q, &k, &v, &t);
    assert!(flat.max_abs_diff(&golden) < 5e-3, "err {}", flat.max_abs_diff(&golden));
}

#[test]
fn mla_decode_artifact_matches_latent_attention() {
    if !ready_or_skip("mla_decode") {
        return;
    }
    let exe = HloExecutable::load(artifact_path(Artifact::MlaDecode).unwrap()).unwrap();
    let mut rng = SplitMix64::new(4);
    let (rows, dc, dr, kv) = (16usize, 64usize, 16usize, 256usize);
    let q_abs = Mat::random(rows, dc + dr, &mut rng);
    let c_kv = Mat::random(kv, dc + dr, &mut rng);
    let golden = exe.run_f32(&[&q_abs, &c_kv], rows, dc).unwrap();
    let v_latent = c_kv.cols_slice(0, dc);
    // Dense + tiled agree with the PJRT-run Pallas kernel.
    let dense = functional::reference_attention(&q_abs, &c_kv, &v_latent, false);
    assert!(dense.max_abs_diff(&golden) < 5e-3);
    let t = FlatTiling { gx: 4, gy: 2, slice_r: 8, slice_c: 64 };
    let flat = functional::flat_attention(&q_abs, &c_kv, &v_latent, &t);
    assert!(flat.max_abs_diff(&golden) < 5e-3);
}

#[test]
fn mla_absorbed_helper_consistency() {
    // No artifacts needed: the mla_absorbed_attention helper equals per-head
    // reference attention over the latent.
    let mut rng = SplitMix64::new(5);
    let (dc, dr, kv) = (32usize, 8usize, 64usize);
    let c_kv = Mat::random(kv, dc + dr, &mut rng);
    let q_abs: Vec<Mat> = (0..3).map(|_| Mat::random(4, dc + dr, &mut rng)).collect();
    let outs = functional::mla_absorbed_attention(&q_abs, &c_kv, dc, false);
    let v = c_kv.cols_slice(0, dc);
    for (qh, oh) in q_abs.iter().zip(&outs) {
        let expect = functional::reference_attention(qh, &c_kv, &v, false);
        assert!(oh.max_abs_diff(&expect) < 1e-5);
    }
}
