//! Cross-module dataflow integration: the paper's qualitative orderings on
//! the full Table I chip (who wins, where, and why).

use flatattention::arch::config::{ChipConfig, Dtype, SimFidelity};
use flatattention::dataflow::{
    choose_tiling, simulate_attention, simulate_gemm, AttentionDataflow, FlatParams, FlatTiling,
};
use flatattention::workload::attention::AttentionShape;

fn run(cfg: &ChipConfig, shape: &AttentionShape, df: AttentionDataflow) -> flatattention::metrics::KernelMetrics {
    simulate_attention(cfg, shape, df, SimFidelity::Full)
}

#[test]
fn fig8_ordering_holds_at_d128_s4096() {
    // The paper's headline Fig. 8 config: FlatAsync < FlatHC < FA-3-ish,
    // FlatSC worst among Flat variants; Flat reduces HBM traffic ~16×.
    let cfg = ChipConfig::table1();
    let shape = AttentionShape::mha_prefill(2, 32, 128, 4096, Dtype::Fp16);
    let full = FlatTiling { gx: 32, gy: 32, slice_r: 128, slice_c: 128 };

    let fa3 = run(&cfg, &shape, AttentionDataflow::Fa3);
    let sc = run(&cfg, &shape, AttentionDataflow::Flat(FlatParams::flat_sc(full)));
    let hc = run(&cfg, &shape, AttentionDataflow::Flat(FlatParams::flat_hc(full)));
    let asym = run(&cfg, &shape, AttentionDataflow::Flat(FlatParams::flat_async(full)));

    assert!(asym.cycles <= hc.cycles, "async {} vs hc {}", asym.cycles, hc.cycles);
    assert!(hc.cycles < sc.cycles, "hc {} vs sc {}", hc.cycles, sc.cycles);
    assert!(asym.cycles < fa3.cycles, "async {} vs fa3 {}", asym.cycles, fa3.cycles);

    // HBM traffic reduction vs FA-3 (paper: 16×; FA-3's smaller block gives
    // a somewhat larger measured factor).
    let traffic_ratio = fa3.hbm_bytes as f64 / asym.hbm_bytes as f64;
    assert!(traffic_ratio > 10.0, "traffic ratio {traffic_ratio}");

    // Speedup over FA-3 in the paper's ballpark (4.1×).
    let speedup = fa3.seconds / asym.seconds;
    assert!(speedup > 2.5 && speedup < 8.0, "speedup {speedup}");
}

#[test]
fn flatasync_hits_high_utilization_at_s4096() {
    // Paper Fig. 9: 92.3% utilization at 32×32, S=4096.
    let cfg = ChipConfig::table1();
    let shape = AttentionShape::mha_prefill(4, 32, 128, 4096, Dtype::Fp16);
    let t = FlatTiling { gx: 32, gy: 32, slice_r: 128, slice_c: 128 };
    let m = run(&cfg, &shape, AttentionDataflow::Flat(FlatParams::flat_async(t)));
    assert!(m.compute_utilization > 0.80, "util {}", m.compute_utilization);
}

#[test]
fn overflattening_collapses_utilization_at_s512() {
    // Paper Fig. 9: 32×32 at S=512 → slice 16 → ~20% active utilization.
    let cfg = ChipConfig::table1();
    let shape = AttentionShape::mha_prefill(4, 32, 128, 512, Dtype::Fp16);
    let over = FlatTiling { gx: 32, gy: 32, slice_r: 16, slice_c: 16 };
    let good = FlatTiling { gx: 4, gy: 4, slice_r: 128, slice_c: 128 };
    let m_over = run(&cfg, &shape, AttentionDataflow::Flat(FlatParams::flat_async(over)));
    let m_good = run(&cfg, &shape, AttentionDataflow::Flat(FlatParams::flat_async(good)));
    assert!(
        m_over.matrix_efficiency_active < 0.30,
        "over-flattened active efficiency {}",
        m_over.matrix_efficiency_active
    );
    assert!(
        m_good.matrix_efficiency_active > 0.85,
        "well-tiled efficiency {}",
        m_good.matrix_efficiency_active
    );
    assert!(m_good.seconds < m_over.seconds, "4x4 should beat 32x32 at S=512");
}

#[test]
fn tiling_strategy_beats_naive_full_flattening_on_short_seqs() {
    let cfg = ChipConfig::table1();
    let shape = AttentionShape::mha_prefill(4, 32, 128, 512, Dtype::Fp16);
    let auto = choose_tiling(&cfg, &shape, true);
    let m_auto = run(&cfg, &shape, AttentionDataflow::Flat(FlatParams::flat_async(auto)));
    let full = FlatTiling { gx: 32, gy: 32, slice_r: 16, slice_c: 16 };
    let m_full = run(&cfg, &shape, AttentionDataflow::Flat(FlatParams::flat_async(full)));
    assert!(m_auto.seconds <= m_full.seconds);
}

#[test]
fn decode_flat_saturates_bandwidth() {
    // MHA decode is memory-bound: the single-row-group dataflow should
    // reach high HBM BW utilization (paper: ~78% average, up to 92%).
    let cfg = ChipConfig::table1_gh200_match();
    let shape = AttentionShape::mha_decode(64, 32, 128, 8192, 1, Dtype::Fp16);
    let m = run(&cfg, &shape, AttentionDataflow::auto_flat(&cfg, &shape));
    assert!(m.hbm_bw_utilization > 0.55, "bw {}", m.hbm_bw_utilization);
}

#[test]
fn mla_decode_flat_is_compute_bound_and_efficient() {
    // Weight-absorbed MLA decode at batch 256 is compute-bound; the paper
    // reports 83% utilization (Fig. 13b).
    let cfg = ChipConfig::wafer_fp8();
    let shape = AttentionShape::mla_absorbed_decode(256, 128, 512, 64, 4096, 2, Dtype::Fp8);
    let m = run(&cfg, &shape, AttentionDataflow::auto_flat(&cfg, &shape));
    assert!(m.compute_utilization > 0.7, "util {}", m.compute_utilization);
}

#[test]
fn gemm_dataflow_efficiency_regimes() {
    let cfg = ChipConfig::table1();
    // Big square GEMM: compute-bound, high utilization.
    let big = simulate_gemm(&cfg, 4096, 4096, 4096, 1, Dtype::Fp16, SimFidelity::Full);
    assert!(big.compute_utilization > 0.6, "big {}", big.compute_utilization);
    // Skinny decode GEMM: weight-streaming memory-bound.
    let skinny = simulate_gemm(&cfg, 64, 7168, 2048, 1, Dtype::Fp8, SimFidelity::Full);
    assert!(skinny.hbm_bw_utilization > 0.3, "skinny bw {}", skinny.hbm_bw_utilization);
}

#[test]
fn fidelities_agree_on_table1_prefill() {
    let cfg = ChipConfig::table1();
    let shape = AttentionShape::mha_prefill(2, 32, 128, 2048, Dtype::Fp16);
    let df = AttentionDataflow::auto_flat(&cfg, &shape);
    let full = simulate_attention(&cfg, &shape, df, SimFidelity::Full);
    let ana = simulate_attention(&cfg, &shape, df, SimFidelity::Analytic);
    let err = (full.cycles as f64 - ana.cycles as f64).abs() / full.cycles as f64;
    assert!(err < 0.4, "full {} ana {}", full.cycles, ana.cycles);
}
