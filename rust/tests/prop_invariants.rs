//! Property-based tests (hand-rolled generator over SplitMix64 — proptest is
//! unavailable in the offline build): randomized invariants on the routing/
//! tiling/scheduling layers and the functional executors.

use flatattention::arch::collective::{multicast_latency_cycles, reduce_latency_cycles, CollectiveImpl};
use flatattention::arch::config::{ChipConfig, Dtype};
use flatattention::cluster::{simulate_cluster, ClusterConfig, FleetMode, KvTransferModel};
use flatattention::dataflow::tiling::{choose_tiling, l1_working_set_kv, Concurrency};
use flatattention::dataflow::FlatTiling;
use flatattention::exec::functional;
use flatattention::exec::tensor::Mat;
use flatattention::multichip::d2d::WaferSystem;
use flatattention::multichip::parallelism::KernelCache;
use flatattention::serve::request::{generate_trace, PrefixProfile, Request, TraceConfig, TrafficPattern};
use flatattention::serve::scheduler::{AdmissionPolicy, PrefixKeying, QueuePolicy, SchedulerConfig};
use flatattention::serve::sim::{simulate, ServeConfig, StageTimeCache};
use flatattention::util::SplitMix64;
use flatattention::workload::attention::AttentionShape;
use flatattention::workload::deepseek::DeepSeekConfig;

const CASES: u64 = 60;

#[test]
fn prop_flat_functional_always_matches_reference() {
    // For arbitrary shapes and group tilings, Algorithm 2's distributed
    // online softmax must equal dense attention.
    let mut rng = SplitMix64::new(2026);
    for case in 0..CASES {
        let sq = 1 + rng.next_range(96) as usize;
        let skv = 1 + rng.next_range(160) as usize;
        let d = [4usize, 8, 16, 32][rng.next_range(4) as usize];
        let dv = [4usize, 8, 16, 32][rng.next_range(4) as usize];
        let gx = 1 + rng.next_range(6) as u32;
        let gy = 1 + rng.next_range(4) as u32;
        let slice_r = 1 + rng.next_range(24) as u32;
        let slice_c = 1 + rng.next_range(24) as u32;
        let q = Mat::random(sq, d, &mut rng);
        let k = Mat::random(skv, d, &mut rng);
        let v = Mat::random(skv, dv, &mut rng);
        let t = FlatTiling { gx, gy, slice_r, slice_c };
        let flat = functional::flat_attention(&q, &k, &v, &t);
        let reference = functional::reference_attention(&q, &k, &v, false);
        let err = flat.max_abs_diff(&reference);
        assert!(
            err < 2e-4,
            "case {case}: sq={sq} skv={skv} d={d} dv={dv} tiling={t:?}: err {err}"
        );
    }
}

#[test]
fn prop_flash_functional_matches_reference() {
    let mut rng = SplitMix64::new(99);
    for _ in 0..CASES {
        let sq = 1 + rng.next_range(80) as usize;
        let skv = 1 + rng.next_range(120) as usize;
        let d = [4usize, 8, 16][rng.next_range(3) as usize];
        let br = 1 + rng.next_range(32) as usize;
        let bc = 1 + rng.next_range(32) as usize;
        let q = Mat::random(sq, d, &mut rng);
        let k = Mat::random(skv, d, &mut rng);
        let v = Mat::random(skv, d, &mut rng);
        let f = functional::flash_attention(&q, &k, &v, br, bc);
        let r = functional::reference_attention(&q, &k, &v, false);
        assert!(f.max_abs_diff(&r) < 2e-4);
    }
}

#[test]
fn prop_tiling_strategy_invariants() {
    // For any attention shape: the chosen group tiles the mesh, slices cover
    // the problem, and the working set fits L1.
    let cfg = ChipConfig::table1();
    let mut rng = SplitMix64::new(7);
    for case in 0..CASES {
        let batch = 1 + rng.next_range(8) as u32;
        let heads = [8u32, 16, 32, 64][rng.next_range(4) as usize];
        let d = [64u32, 128][rng.next_range(2) as usize];
        let shape = match rng.next_range(4) {
            0 => AttentionShape::mha_prefill(batch, heads, d, 256 << rng.next_range(5), Dtype::Fp16),
            1 => AttentionShape::mha_decode(batch, heads, d, 1024 << rng.next_range(4), 1 + rng.next_range(4) as u32, Dtype::Fp16),
            2 => AttentionShape::gqa_decode(batch, heads, [2u32, 4, 8][rng.next_range(3) as usize].min(heads), d, 4096, 2, Dtype::Fp16),
            _ => AttentionShape::mla_absorbed_decode(batch * 16, 128, 512, 64, 4096, 2, Dtype::Fp8),
        };
        let t = choose_tiling(&cfg, &shape, true);
        assert!(cfg.mesh_x % t.gx == 0, "case {case}: gx {} does not tile mesh", t.gx);
        assert!(cfg.mesh_y % t.gy == 0, "case {case}: gy {} does not tile mesh", t.gy);
        assert!(t.slice_r >= 1 && t.slice_c >= 1);
        let kv_cols = shape.kv_row_bytes() / shape.dtype.bytes();
        let ws = l1_working_set_kv(
            t.slice_r as u64,
            t.slice_c as u64,
            shape.head_dim as u64,
            shape.v_head_dim as u64,
            kv_cols,
            shape.dtype,
            true,
            Concurrency::TwoRowBlocks,
        );
        assert!(ws.fits(&cfg.tile), "case {case}: {t:?} working set {} KiB", ws.total_kib());
        // Slices never exceed the problem.
        assert!(t.slice_r as u64 <= shape.effective_q_rows().max(1));
        assert!(t.slice_c as u64 <= shape.seq_kv.max(1) as u64);
    }
}

#[test]
fn prop_io_model_monotonicity() {
    // Flattening never increases modeled HBM traffic; traffic never drops
    // below the compulsory minimum.
    let mut rng = SplitMix64::new(13);
    for _ in 0..CASES {
        let shape = AttentionShape::mha_prefill(
            1 + rng.next_range(4) as u32,
            8 << rng.next_range(3),
            [64u32, 128][rng.next_range(2) as usize],
            256 << rng.next_range(6),
            Dtype::Fp16,
        );
        let m = [32u32, 64, 128][rng.next_range(3) as usize];
        let mut last = u64::MAX;
        for n in [1u32, 2, 4, 8, 16, 32] {
            let io = shape.io_bytes_with_flattening(m, n);
            assert!(io <= last);
            assert!(io >= shape.ideal_io_bytes());
            last = io;
        }
    }
}

#[test]
fn prop_collective_latency_monotonicity() {
    // Latency grows with width and payload for every implementation, and
    // HW ≤ SW.Tree ≤ SW.Seq at equal parameters (large payloads).
    let cfg = ChipConfig::table1();
    let mut rng = SplitMix64::new(17);
    for _ in 0..CASES {
        let w1 = 2 + rng.next_range(15) as u32;
        let w2 = w1 + 1 + rng.next_range(16) as u32;
        let b1 = 1024 << rng.next_range(8);
        let b2 = b1 * 2;
        for imp in [CollectiveImpl::Hw, CollectiveImpl::SwTree, CollectiveImpl::SwSeq] {
            assert!(multicast_latency_cycles(&cfg, imp, w1, b1) <= multicast_latency_cycles(&cfg, imp, w2, b1));
            assert!(multicast_latency_cycles(&cfg, imp, w1, b1) <= multicast_latency_cycles(&cfg, imp, w1, b2));
            assert!(reduce_latency_cycles(&cfg, imp, w1, b1, Dtype::Fp16) <= reduce_latency_cycles(&cfg, imp, w2, b1, Dtype::Fp16));
        }
        let big = 1 << 20;
        let hw = multicast_latency_cycles(&cfg, CollectiveImpl::Hw, w2, big);
        let tree = multicast_latency_cycles(&cfg, CollectiveImpl::SwTree, w2, big);
        let seq = multicast_latency_cycles(&cfg, CollectiveImpl::SwSeq, w2, big);
        assert!(hw <= tree && tree <= seq, "w={w2}: hw {hw} tree {tree} seq {seq}");
    }
}

#[test]
fn prop_attention_flops_scaling() {
    // FLOPs scale linearly in batch, heads and kv length for decode shapes.
    let mut rng = SplitMix64::new(23);
    for _ in 0..CASES {
        let b = 1 + rng.next_range(16) as u32;
        let h = 4 << rng.next_range(4);
        let kv = 512 << rng.next_range(5);
        let base = AttentionShape::mha_decode(b, h, 128, kv, 1, Dtype::Fp16);
        let b2 = AttentionShape::mha_decode(2 * b, h, 128, kv, 1, Dtype::Fp16);
        let kv2 = AttentionShape::mha_decode(b, h, 128, 2 * kv, 1, Dtype::Fp16);
        assert_eq!(b2.flops(), 2 * base.flops());
        assert_eq!(kv2.flops(), 2 * base.flops());
    }
}

#[test]
fn prop_causal_flops_half_of_full() {
    let mut rng = SplitMix64::new(29);
    for _ in 0..CASES {
        let s = 128 << rng.next_range(5);
        let mut shape = AttentionShape::mha_prefill(2, 8, 64, s, Dtype::Fp16);
        let causal = shape.flops();
        shape.causal = false;
        assert_eq!(causal * 2, shape.flops());
    }
}

// ---------------------------------------------------------------------------
// Serving-layer properties (prefix cache, preemption, queue policies).
// ---------------------------------------------------------------------------

/// A family of well-spaced requests all sharing prefix id 1 of
/// `prefix_tokens` leading tokens (spacing guarantees request i finishes
/// prefilling before i+1 arrives, so reuse is sequential and deterministic).
fn shared_prefix_trace(n: u64, prefix_tokens: u32) -> Vec<Request> {
    (0..n)
        .map(|i| Request {
            prefix_id: 1,
            prefix_tokens,
            ..Request::new(i, i as f64, prefix_tokens + 128, 16)
        })
        .collect()
}

#[test]
fn prop_prefix_hit_ratio_monotone_in_shared_prefix_length() {
    // Longer shared prefixes can only increase the cache-served token count
    // and the hit ratio (whole-block rounding makes it stepwise).
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let cfg = ServeConfig::default();
    let kernels = KernelCache::new();
    let stages = StageTimeCache::new();
    let mut rng = SplitMix64::new(43);
    let n = 8u64;
    let horizon = 60.0;
    for _ in 0..6 {
        let mut lens: Vec<u32> = (0..3).map(|_| 64 + rng.next_range(1984) as u32).collect();
        lens.sort_unstable();
        let mut last_hits = 0u64;
        let mut last_ratio = 0.0f64;
        for len in lens {
            let trace = shared_prefix_trace(n, len);
            let (o, _) = simulate(&sys, &ds, &trace, &cfg, horizon, "pfx", 1.0, &kernels, &stages);
            assert_eq!(o.completed, n as usize, "len {len}: all requests must drain");
            assert!(o.conserves_requests());
            assert!(
                o.prefix_hit_tokens >= last_hits,
                "hit tokens regressed with longer prefix: {} < {last_hits} at len {len}",
                o.prefix_hit_tokens
            );
            assert!(
                o.prefix_hit_rate() >= last_ratio - 1e-12,
                "hit ratio regressed: {} < {last_ratio} at len {len}",
                o.prefix_hit_rate()
            );
            // Whole-block accounting: with ≥1 shareable block, everyone but
            // the cold first request hits the full shareable prefix.
            let block = cfg.scheduler.prefix_block_tokens;
            let shareable = (len / block) * block;
            assert_eq!(o.prefix_hit_tokens, (n - 1) * shareable as u64);
            assert_eq!(o.prefix_miss_tokens, shareable as u64);
            last_hits = o.prefix_hit_tokens;
            last_ratio = o.prefix_hit_rate();
        }
    }
}

#[test]
fn prop_conservation_and_kv_safety_under_preemption_and_reuse() {
    // Memory-starved wafer + on-demand admission + shared-prefix traffic:
    // requests are preempted, recomputed and reuse cached prefixes — the
    // conservation identity and the KV capacity bound must survive all of
    // it, with the trie active.
    let ds = DeepSeekConfig::v3_671b();
    let mut sys = WaferSystem::paper();
    sys.chip.hbm.capacity_gib_per_stack = 10;
    let kernels = KernelCache::new();
    let stages = StageTimeCache::new();
    for (seed, policy) in [
        (3u64, AdmissionPolicy::OnDemandPreempt),
        (17, AdmissionPolicy::OnDemandPreempt),
        (17, AdmissionPolicy::ReserveFull),
    ] {
        let tc = TraceConfig::new(seed, TrafficPattern::Poisson, 2000.0, 6.0)
            .with_prefixes(PrefixProfile::agentic());
        let trace = generate_trace(&tc);
        let cfg = ServeConfig {
            scheduler: SchedulerConfig { policy, ..Default::default() },
            ..Default::default()
        };
        let (o, recs) = simulate(&sys, &ds, &trace, &cfg, 6.0, "pressure", 2000.0, &kernels, &stages);
        assert!(o.conserves_requests(), "seed {seed} {policy:?}: {o:?}");
        assert!(!o.kv_over_capacity, "seed {seed} {policy:?} overflowed KV with trie active");
        assert!(o.peak_kv_occupancy <= 1.0 + 1e-9, "seed {seed}: peak {}", o.peak_kv_occupancy);
        // Record-level token/causality conservation.
        let completed = recs.iter().filter(|r| r.completion_s.is_some()).count();
        assert_eq!(completed, o.completed);
        for r in &recs {
            if let Some(c) = r.completion_s {
                let f = r.first_token_s.expect("completion implies a first token");
                assert!(f <= c + 1e-12);
                assert!(f >= r.arrival_s - 1e-12, "first token before arrival");
            }
        }
    }
}

#[test]
fn prop_cluster_conservation_and_transfer_bytes_across_seeds() {
    // Randomized fleet shapes × seeds: the fleet-wide conservation identity
    // (admitted = completed + rejected + in-flight at horizon) and the
    // transfer-bytes == latent-KV layout identity must hold for every mode.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let kernels = KernelCache::new();
    let stages = StageTimeCache::new();
    let layout = KvTransferModel::layout_bytes_per_token(&ds, ServeConfig::default().dtype);
    let mut rng = SplitMix64::new(4040);
    for case in 0..4 {
        let seed = rng.next_u64();
        let rate = 200.0 + rng.next_range(800) as f64;
        let mode = match rng.next_range(3) {
            0 => FleetMode::Colocated { instances: 1 + rng.next_range(3) as u32 },
            1 => FleetMode::Disaggregated { prefill: 1, decode: 1 + rng.next_range(2) as u32 },
            _ => FleetMode::Disaggregated { prefill: 2, decode: 1 },
        };
        let tc = TraceConfig::new(seed, TrafficPattern::Poisson, rate, 3.0)
            .with_prefixes(PrefixProfile::agentic());
        let trace = generate_trace(&tc);
        let ccfg = ClusterConfig { mode, ..ClusterConfig::colocated(2, &ds) };
        let (o, recs) = simulate_cluster(&sys, &ds, &trace, &ccfg, 3.0, rate, &kernels, &stages);
        assert!(o.conserves_requests(), "case {case} {mode:?}: {o:?}");
        assert!(!o.kv_over_capacity, "case {case} {mode:?} overflowed KV");
        let backlog: usize = o.instances.iter().map(|i| i.backlog).sum();
        assert_eq!(o.in_flight, backlog + o.in_transfer, "case {case} {mode:?}");
        for r in &recs {
            if r.transfer_bytes > 0 {
                assert_eq!(
                    r.transfer_bytes,
                    r.prompt_tokens as u64 * layout,
                    "case {case}: migration shipped non-layout bytes"
                );
            }
            if let (Some(f), Some(c)) = (r.first_token_s, r.completion_s) {
                assert!(r.arrival_s <= f + 1e-12 && f <= c + 1e-12, "case {case}: causality");
            }
        }
    }
}

#[test]
fn prop_token_hash_keying_hit_rate_dominates_exact_id() {
    // On shared-prefix traffic whose families alias onto fewer underlying
    // contents, hashed-token-block keying must serve strictly more prefix
    // tokens from the cache than the exact-id baseline (and never fewer on
    // any trace) — the cross-request sharing the ROADMAP open item asks for.
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let kernels = KernelCache::new();
    let stages = StageTimeCache::new();
    for seed in [5u64, 67] {
        let tc = TraceConfig::new(seed, TrafficPattern::Poisson, 300.0, 5.0)
            .with_prefixes(PrefixProfile::agentic_aliased());
        let trace = generate_trace(&tc);
        let run = |keying: PrefixKeying| {
            let cfg = ServeConfig {
                scheduler: SchedulerConfig { prefix_keying: keying, ..Default::default() },
                ..Default::default()
            };
            let (o, _) = simulate(&sys, &ds, &trace, &cfg, 5.0, "k", 300.0, &kernels, &stages);
            assert!(o.conserves_requests());
            assert!(!o.kv_over_capacity);
            o
        };
        let exact = run(PrefixKeying::ExactId);
        let hashed = run(PrefixKeying::TokenHash);
        assert!(
            hashed.prefix_hit_tokens > exact.prefix_hit_tokens,
            "seed {seed}: hashed {} must beat exact {} on aliased families",
            hashed.prefix_hit_tokens,
            exact.prefix_hit_tokens
        );
        assert!(
            hashed.prefix_hit_rate() > exact.prefix_hit_rate(),
            "seed {seed}: hit rate must strictly improve"
        );
        // More cache hits can only reduce the prefill work actually billed.
        assert!(hashed.prefix_miss_tokens <= exact.prefix_miss_tokens);
    }
}

#[test]
fn prop_sjf_does_not_increase_mean_ttft_vs_fcfs() {
    // On identical overloaded traces, shortest-prompt-first can only help
    // mean TTFT (small tolerance for batching/bucketing discreteness).
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let kernels = KernelCache::new();
    let stages = StageTimeCache::new();
    for seed in [7u64, 29] {
        let trace =
            generate_trace(&TraceConfig::new(seed, TrafficPattern::Poisson, 1500.0, 5.0));
        let run = |queue_policy: QueuePolicy| {
            let cfg = ServeConfig {
                scheduler: SchedulerConfig { queue_policy, ..Default::default() },
                ..Default::default()
            };
            let (o, _) =
                simulate(&sys, &ds, &trace, &cfg, 5.0, "q", 1500.0, &kernels, &stages);
            assert!(o.conserves_requests());
            o
        };
        let fcfs = run(QueuePolicy::Fcfs);
        let sjf = run(QueuePolicy::Sjf);
        assert!(fcfs.ttft_ms.n > 100, "need a populated TTFT sample");
        assert!(
            sjf.ttft_ms.mean <= fcfs.ttft_ms.mean * 1.05,
            "seed {seed}: SJF mean TTFT {} exceeds FCFS {}",
            sjf.ttft_ms.mean,
            fcfs.ttft_ms.mean
        );
    }
}
