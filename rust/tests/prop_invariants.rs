//! Property-based tests (hand-rolled generator over SplitMix64 — proptest is
//! unavailable in the offline build): randomized invariants on the routing/
//! tiling/scheduling layers and the functional executors.

use flatattention::arch::collective::{multicast_latency_cycles, reduce_latency_cycles, CollectiveImpl};
use flatattention::arch::config::{ChipConfig, Dtype};
use flatattention::dataflow::tiling::{choose_tiling, l1_working_set_kv, Concurrency};
use flatattention::dataflow::FlatTiling;
use flatattention::exec::functional;
use flatattention::exec::tensor::Mat;
use flatattention::util::SplitMix64;
use flatattention::workload::attention::AttentionShape;

const CASES: u64 = 60;

#[test]
fn prop_flat_functional_always_matches_reference() {
    // For arbitrary shapes and group tilings, Algorithm 2's distributed
    // online softmax must equal dense attention.
    let mut rng = SplitMix64::new(2026);
    for case in 0..CASES {
        let sq = 1 + rng.next_range(96) as usize;
        let skv = 1 + rng.next_range(160) as usize;
        let d = [4usize, 8, 16, 32][rng.next_range(4) as usize];
        let dv = [4usize, 8, 16, 32][rng.next_range(4) as usize];
        let gx = 1 + rng.next_range(6) as u32;
        let gy = 1 + rng.next_range(4) as u32;
        let slice_r = 1 + rng.next_range(24) as u32;
        let slice_c = 1 + rng.next_range(24) as u32;
        let q = Mat::random(sq, d, &mut rng);
        let k = Mat::random(skv, d, &mut rng);
        let v = Mat::random(skv, dv, &mut rng);
        let t = FlatTiling { gx, gy, slice_r, slice_c };
        let flat = functional::flat_attention(&q, &k, &v, &t);
        let reference = functional::reference_attention(&q, &k, &v, false);
        let err = flat.max_abs_diff(&reference);
        assert!(
            err < 2e-4,
            "case {case}: sq={sq} skv={skv} d={d} dv={dv} tiling={t:?}: err {err}"
        );
    }
}

#[test]
fn prop_flash_functional_matches_reference() {
    let mut rng = SplitMix64::new(99);
    for _ in 0..CASES {
        let sq = 1 + rng.next_range(80) as usize;
        let skv = 1 + rng.next_range(120) as usize;
        let d = [4usize, 8, 16][rng.next_range(3) as usize];
        let br = 1 + rng.next_range(32) as usize;
        let bc = 1 + rng.next_range(32) as usize;
        let q = Mat::random(sq, d, &mut rng);
        let k = Mat::random(skv, d, &mut rng);
        let v = Mat::random(skv, d, &mut rng);
        let f = functional::flash_attention(&q, &k, &v, br, bc);
        let r = functional::reference_attention(&q, &k, &v, false);
        assert!(f.max_abs_diff(&r) < 2e-4);
    }
}

#[test]
fn prop_tiling_strategy_invariants() {
    // For any attention shape: the chosen group tiles the mesh, slices cover
    // the problem, and the working set fits L1.
    let cfg = ChipConfig::table1();
    let mut rng = SplitMix64::new(7);
    for case in 0..CASES {
        let batch = 1 + rng.next_range(8) as u32;
        let heads = [8u32, 16, 32, 64][rng.next_range(4) as usize];
        let d = [64u32, 128][rng.next_range(2) as usize];
        let shape = match rng.next_range(4) {
            0 => AttentionShape::mha_prefill(batch, heads, d, 256 << rng.next_range(5), Dtype::Fp16),
            1 => AttentionShape::mha_decode(batch, heads, d, 1024 << rng.next_range(4), 1 + rng.next_range(4) as u32, Dtype::Fp16),
            2 => AttentionShape::gqa_decode(batch, heads, [2u32, 4, 8][rng.next_range(3) as usize].min(heads), d, 4096, 2, Dtype::Fp16),
            _ => AttentionShape::mla_absorbed_decode(batch * 16, 128, 512, 64, 4096, 2, Dtype::Fp8),
        };
        let t = choose_tiling(&cfg, &shape, true);
        assert!(cfg.mesh_x % t.gx == 0, "case {case}: gx {} does not tile mesh", t.gx);
        assert!(cfg.mesh_y % t.gy == 0, "case {case}: gy {} does not tile mesh", t.gy);
        assert!(t.slice_r >= 1 && t.slice_c >= 1);
        let kv_cols = shape.kv_row_bytes() / shape.dtype.bytes();
        let ws = l1_working_set_kv(
            t.slice_r as u64,
            t.slice_c as u64,
            shape.head_dim as u64,
            shape.v_head_dim as u64,
            kv_cols,
            shape.dtype,
            true,
            Concurrency::TwoRowBlocks,
        );
        assert!(ws.fits(&cfg.tile), "case {case}: {t:?} working set {} KiB", ws.total_kib());
        // Slices never exceed the problem.
        assert!(t.slice_r as u64 <= shape.effective_q_rows().max(1));
        assert!(t.slice_c as u64 <= shape.seq_kv.max(1) as u64);
    }
}

#[test]
fn prop_io_model_monotonicity() {
    // Flattening never increases modeled HBM traffic; traffic never drops
    // below the compulsory minimum.
    let mut rng = SplitMix64::new(13);
    for _ in 0..CASES {
        let shape = AttentionShape::mha_prefill(
            1 + rng.next_range(4) as u32,
            8 << rng.next_range(3),
            [64u32, 128][rng.next_range(2) as usize],
            256 << rng.next_range(6),
            Dtype::Fp16,
        );
        let m = [32u32, 64, 128][rng.next_range(3) as usize];
        let mut last = u64::MAX;
        for n in [1u32, 2, 4, 8, 16, 32] {
            let io = shape.io_bytes_with_flattening(m, n);
            assert!(io <= last);
            assert!(io >= shape.ideal_io_bytes());
            last = io;
        }
    }
}

#[test]
fn prop_collective_latency_monotonicity() {
    // Latency grows with width and payload for every implementation, and
    // HW ≤ SW.Tree ≤ SW.Seq at equal parameters (large payloads).
    let cfg = ChipConfig::table1();
    let mut rng = SplitMix64::new(17);
    for _ in 0..CASES {
        let w1 = 2 + rng.next_range(15) as u32;
        let w2 = w1 + 1 + rng.next_range(16) as u32;
        let b1 = 1024 << rng.next_range(8);
        let b2 = b1 * 2;
        for imp in [CollectiveImpl::Hw, CollectiveImpl::SwTree, CollectiveImpl::SwSeq] {
            assert!(multicast_latency_cycles(&cfg, imp, w1, b1) <= multicast_latency_cycles(&cfg, imp, w2, b1));
            assert!(multicast_latency_cycles(&cfg, imp, w1, b1) <= multicast_latency_cycles(&cfg, imp, w1, b2));
            assert!(reduce_latency_cycles(&cfg, imp, w1, b1, Dtype::Fp16) <= reduce_latency_cycles(&cfg, imp, w2, b1, Dtype::Fp16));
        }
        let big = 1 << 20;
        let hw = multicast_latency_cycles(&cfg, CollectiveImpl::Hw, w2, big);
        let tree = multicast_latency_cycles(&cfg, CollectiveImpl::SwTree, w2, big);
        let seq = multicast_latency_cycles(&cfg, CollectiveImpl::SwSeq, w2, big);
        assert!(hw <= tree && tree <= seq, "w={w2}: hw {hw} tree {tree} seq {seq}");
    }
}

#[test]
fn prop_attention_flops_scaling() {
    // FLOPs scale linearly in batch, heads and kv length for decode shapes.
    let mut rng = SplitMix64::new(23);
    for _ in 0..CASES {
        let b = 1 + rng.next_range(16) as u32;
        let h = 4 << rng.next_range(4);
        let kv = 512 << rng.next_range(5);
        let base = AttentionShape::mha_decode(b, h, 128, kv, 1, Dtype::Fp16);
        let b2 = AttentionShape::mha_decode(2 * b, h, 128, kv, 1, Dtype::Fp16);
        let kv2 = AttentionShape::mha_decode(b, h, 128, 2 * kv, 1, Dtype::Fp16);
        assert_eq!(b2.flops(), 2 * base.flops());
        assert_eq!(kv2.flops(), 2 * base.flops());
    }
}

#[test]
fn prop_causal_flops_half_of_full() {
    let mut rng = SplitMix64::new(29);
    for _ in 0..CASES {
        let s = 128 << rng.next_range(5);
        let mut shape = AttentionShape::mha_prefill(2, 8, 64, s, Dtype::Fp16);
        let causal = shape.flops();
        shape.causal = false;
        assert_eq!(causal * 2, shape.flops());
    }
}
