//! Integration tests for the DES core: determinism, bound sanity on random
//! DAGs, and accounting consistency.

use flatattention::sim::{Category, Graph, Op, OpId, ResourceKind, ResourceTable};
use flatattention::util::SplitMix64;

/// Build a random layered DAG over a few resources.
fn random_graph(seed: u64, n_ops: usize, n_res: usize) -> (Graph, u64, u64) {
    let mut rng = SplitMix64::new(seed);
    let mut table = ResourceTable::new();
    let res: Vec<_> = (0..n_res).map(|i| table.add(ResourceKind::Generic(i as u32))).collect();
    let mut g = Graph::new(table);
    let mut ids: Vec<OpId> = Vec::new();
    let mut total: u64 = 0;
    let mut critical: Vec<u64> = Vec::new();
    for i in 0..n_ops {
        let dur = 1 + rng.next_range(100);
        total += dur;
        let ndeps = if i == 0 { 0 } else { rng.next_range(3.min(i as u64) + 1) as usize };
        let mut deps = Vec::new();
        let mut cp = 0u64;
        for _ in 0..ndeps {
            let d = rng.next_range(i as u64) as usize;
            deps.push(ids[d]);
            cp = cp.max(critical[d]);
        }
        let r = res[rng.next_range(n_res as u64) as usize];
        let cat = if rng.next_f64() < 0.5 { Category::Gemm } else { Category::Vector };
        let id = g.push(Op::new(Some(r), dur, cat).flops(dur), &deps);
        ids.push(id);
        critical.push(cp + dur);
    }
    (g, total, critical.into_iter().max().unwrap_or(0))
}

#[test]
fn deterministic_across_runs() {
    for seed in 0..5 {
        let (g1, _, _) = random_graph(seed, 500, 7);
        let (g2, _, _) = random_graph(seed, 500, 7);
        let r1 = g1.simulate();
        let r2 = g2.simulate();
        assert_eq!(r1.makespan, r2.makespan);
        assert_eq!(r1.busy_by_cat, r2.busy_by_cat);
        assert_eq!(r1.exposed.per_cat, r2.exposed.per_cat);
    }
}

#[test]
fn makespan_bounded_by_critical_path_and_serial_sum() {
    for seed in 10..30 {
        let (g, total, critical) = random_graph(seed, 300, 5);
        let r = g.simulate();
        assert!(r.makespan >= critical, "makespan {} < critical path {critical}", r.makespan);
        assert!(r.makespan <= total, "makespan {} > serial sum {total}", r.makespan);
    }
}

#[test]
fn single_resource_serializes_to_total() {
    let (g, total, _) = random_graph(99, 200, 1);
    let r = g.simulate();
    assert_eq!(r.makespan, total);
}

#[test]
fn exposed_sums_to_at_most_makespan() {
    for seed in 40..50 {
        let (g, _, _) = random_graph(seed, 400, 4);
        let r = g.simulate();
        let exposed_sum: u64 = r.exposed.per_cat.iter().sum();
        assert!(exposed_sum <= r.makespan);
        assert_eq!(exposed_sum, r.exposed.union_busy);
    }
}

#[test]
fn busy_by_cat_ge_exposed() {
    for seed in 60..70 {
        let (g, _, _) = random_graph(seed, 400, 4);
        let r = g.simulate();
        for (i, &b) in r.busy_by_cat.iter().enumerate() {
            assert!(b >= r.exposed.per_cat[i], "cat {i}: busy {b} < exposed {}", r.exposed.per_cat[i]);
        }
    }
}

#[test]
fn flops_accounting_is_exact() {
    let (g, total, _) = random_graph(7, 250, 3);
    let r = g.simulate();
    // random_graph sets flops == duration per op.
    assert_eq!(r.flops, total);
}

#[test]
fn more_resources_never_slower() {
    for seed in 80..85 {
        let (g_few, _, _) = random_graph(seed, 300, 2);
        let (g_many, _, _) = random_graph(seed, 300, 2);
        let few = g_few.simulate().makespan;
        let many = g_many.simulate().makespan;
        assert_eq!(few, many); // identical construction is a smoke check
        // Rebuild with more resources but the same op/dep structure is not
        // directly comparable (resource assignment differs); instead check
        // the degenerate bound: 1 resource ≥ N resources for the same seed
        // and op count via serial sum property (covered above).
    }
}
