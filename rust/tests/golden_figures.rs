//! Golden-figure regression suite: pins the analytical model to the paper's
//! anchor points with tolerance bands, so future refactors cannot silently
//! drift the headline numbers (the FuseMax lesson: a cost model is only
//! trustworthy once it is pinned to its analytical figures by tests).
//!
//! Each test names the figure/table it guards. Bands are deliberately wider
//! than the paper's single numbers — they catch structural drift (a broken
//! dataflow, a mis-billed phase), not last-digit noise.

use flatattention::arch::config::{ChipConfig, Dtype, SimFidelity};
use flatattention::baseline::gh200::{self, Gh200};
use flatattention::cluster::{
    simulate_cluster, simulate_shared_pool, ClusterConfig, FleetMode, Router, RoutingPolicy, SharedPoolSpec,
};
use flatattention::dataflow::{simulate_attention, AttentionDataflow, FlatParams, FlatTiling};
use flatattention::multichip::d2d::WaferSystem;
use flatattention::multichip::parallelism::{AttentionChoice, KernelCache, ParallelismPlan};
use flatattention::multichip::wafer::{batch_sweep, best_under_tpot, ours1};
use flatattention::serve::prefill::PrefillEngine;
use flatattention::serve::request::{generate_trace, TraceConfig, TrafficPattern};
use flatattention::serve::sim::{load_sweep, saturation_knee, ServeConfig, StageTimeCache};
use flatattention::workload::attention::AttentionShape;
use flatattention::workload::deepseek::DeepSeekConfig;

/// Fig. 9 anchor: the paper's peak-utilization FlatAttention configuration
/// (FlatAsync, 32×32 group, 128×128 slices, S=4096, D=128) reaches ≥92%
/// matrix utilization on the Table I chip. Our DES lands in the same
/// regime; the band guards against the dataflow ever falling out of it.
#[test]
fn golden_fig9_peak_flatattention_utilization() {
    let cfg = ChipConfig::table1();
    let shape = AttentionShape::mha_prefill(4, 32, 128, 4096, Dtype::Fp16);
    let t = FlatTiling { gx: 32, gy: 32, slice_r: 128, slice_c: 128 };
    let m = simulate_attention(&cfg, &shape, AttentionDataflow::Flat(FlatParams::flat_async(t)), SimFidelity::Full);
    assert!(
        m.compute_utilization > 0.80 && m.compute_utilization <= 1.0,
        "peak-config utilization drifted out of band: {}",
        m.compute_utilization
    );
    // The same config's active-engine efficiency must also stay high.
    assert!(
        m.matrix_efficiency_active > 0.80,
        "active efficiency {}",
        m.matrix_efficiency_active
    );
}

/// Fig. 1b anchor: FA-3 prefill efficiency on GH200 sits 26–64% below the
/// roofline, i.e. inside the [0.36, 0.74] efficiency envelope, for the
/// figure's prefill shapes.
#[test]
fn golden_fig1b_fa3_prefill_efficiency_envelope() {
    let gh = Gh200::new();
    for d in [64u32, 128] {
        for s in [2048u32, 4096, 8192] {
            let shape = AttentionShape::mha_prefill(2, 32, d, s, Dtype::Fp16);
            let a = gh200::attention(&gh, &shape);
            assert_eq!(a.kernel, "FlashAttention-3");
            assert!(
                a.efficiency >= 0.36 && a.efficiency <= 0.74,
                "FA-3 prefill d{d} s{s} efficiency {} left the Fig. 1b envelope",
                a.efficiency
            );
        }
    }
}

/// §III-A / §V-A closed-form anchors: the FlashAttention→FlatAttention HBM
/// traffic reductions at the paper's two quoted points (6.6× at N=8 and
/// ~16× at full 32-wide flattening, D=128, S=4096).
#[test]
fn golden_hbm_traffic_reduction_anchors() {
    let s1 = AttentionShape::mha_prefill(1, 1, 128, 4096, Dtype::Fp16);
    let r8 = s1.flash_io_bytes(128) as f64 / s1.io_bytes_with_flattening(128, 8) as f64;
    assert!((r8 - 6.6).abs() < 0.2, "N=8 reduction {r8} (paper: 6.6x)");
    let s2 = AttentionShape::mha_prefill(2, 32, 128, 4096, Dtype::Fp16);
    let r32 = s2.flash_io_bytes(128) as f64 / s2.io_bytes_with_flattening(128, 32) as f64;
    assert!((r32 - 16.5).abs() < 0.5, "N=32 reduction {r32} (paper: 16x)");
}

/// Fig. 13a sweep shape: TPOT grows monotonically with batch for both
/// dataflows, FlatAttention beats FlashMLA at the paper's high-batch point
/// (within the repro's measured 1.3–3.0× band), and throughput grows from
/// mid to high batch.
#[test]
fn golden_fig13a_sweep_monotonicity_and_ordering() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let plan = ParallelismPlan::new(32, 2);
    let flat = batch_sweep(&sys, &ds, plan, 4096, AttentionChoice::Flat, SimFidelity::Analytic);
    let mla = batch_sweep(&sys, &ds, plan, 4096, AttentionChoice::FlashMla, SimFidelity::Analytic);
    for sweep in [&flat, &mla] {
        for w in sweep.windows(2) {
            assert!(
                w[1].tpot_ms >= 0.999 * w[0].tpot_ms,
                "TPOT regressed with batch: {} → {}",
                w[0].tpot_ms,
                w[1].tpot_ms
            );
        }
    }
    let f256 = flat.iter().find(|o| o.batch_per_chip == 256).unwrap();
    let m256 = mla.iter().find(|o| o.batch_per_chip == 256).unwrap();
    let speedup = f256.system_tokens_per_s / m256.system_tokens_per_s;
    assert!(speedup > 1.3 && speedup < 3.0, "Flat/FlashMLA speedup {speedup} left the band");
    let f64b = flat.iter().find(|o| o.batch_per_chip == 64).unwrap();
    assert!(f256.system_tokens_per_s > f64b.system_tokens_per_s, "throughput must grow 64→256");
}

/// Table II anchor: the Ours1 sweep holds a <50 ms TPOT operating point
/// with per-chip throughput in the thousands of tokens/s.
#[test]
fn golden_table2_ours1_operating_point() {
    let sweep = ours1(SimFidelity::Analytic);
    let best = best_under_tpot(&sweep, 50.0).expect("Ours1 must hold a sub-50ms point");
    assert!(best.tpot_ms < 50.0);
    assert!(
        best.per_chip_tokens_per_s > 3000.0,
        "per-chip throughput {} fell out of the Table II regime",
        best.per_chip_tokens_per_s
    );
}

/// Serving acceptance anchor: a prefill chunk's billed stage time equals a
/// direct dataflow evaluation of the identical (bucketed) shape within 1% —
/// the serving loop bills real dataflow numbers, not an approximation.
#[test]
fn golden_prefill_chunk_billing_matches_dataflow() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let cfg = ServeConfig::default();
    let engine = PrefillEngine::new(
        &sys,
        &ds,
        cfg.plan,
        cfg.choice,
        cfg.fidelity,
        cfg.dtype,
        KernelCache::new(),
        StageTimeCache::new(),
    );
    for (chunk, ctx) in [(1024u64, 1024.0f64), (1024, 8192.0), (512, 3000.0), (256, 70_000.0)] {
        let billed = engine.chunk_stage_seconds(chunk, ctx);
        let (cb, xb) = engine.bucketed(chunk, ctx);
        let direct = engine.evaluate_chunk(cb, xb);
        assert!(billed > 0.0, "chunk {chunk} ctx {ctx} billed nothing");
        assert!(
            (billed - direct).abs() <= 0.01 * direct,
            "chunk {chunk} ctx {ctx}: billed {billed} vs direct dataflow {direct}"
        );
    }
    // And prefill is billed at prefill economics: a full chunk at fresh
    // context costs materially more than one decode row's marginal cost
    // would suggest is free — i.e. strictly positive and growing in depth.
    let shallow = engine.chunk_stage_seconds(1024, 1024.0);
    let deep = engine.chunk_stage_seconds(1024, 65_536.0);
    assert!(deep > shallow, "chunk cost must grow with context offset");
}

/// Cluster anchor: the colocated-vs-disaggregated crossover exists and is
/// seed-stable on a 2-instance fleet — re-validated under the interleaved
/// single-clock engine (the qualitative ordering survived the refactor;
/// the handoff now additionally rides the congested shared link, which
/// only strengthens the low-load TTFT side). At high offered load, the
/// dedicated decode pool's iterations carry no chunked-prefill
/// interference, so disaggregation improves p99 TPOT over the colocated
/// fleet; at low load nothing queues, so the KV handoff is pure
/// first-token overhead and the colocated fleet wins TTFT.
#[test]
fn golden_cluster_disagg_crossover_anchor() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let kernels = KernelCache::new();
    let stages = StageTimeCache::new();
    let horizon = 4.0;
    let seed = 2026u64;
    let run = |mode: FleetMode, rate: f64| {
        let trace = generate_trace(&TraceConfig::new(seed, TrafficPattern::Poisson, rate, horizon));
        let ccfg = ClusterConfig { mode, ..ClusterConfig::colocated(2, &ds) };
        let (o, _) = simulate_cluster(&sys, &ds, &trace, &ccfg, horizon, rate, &kernels, &stages);
        assert!(o.conserves_requests(), "{mode:?} @ {rate}: {o:?}");
        assert!(!o.kv_over_capacity);
        o
    };
    let colocated = FleetMode::Colocated { instances: 2 };
    let disagg = FleetMode::Disaggregated { prefill: 1, decode: 1 };
    // Low load: every request pays the exposed KV handoff, nothing queues —
    // colocated must hold strictly lower mean TTFT.
    let (colo_lo, dis_lo) = (run(colocated, 40.0), run(disagg, 40.0));
    assert!(colo_lo.completed > 50 && dis_lo.completed > 50, "low-load runs must drain");
    assert!(
        colo_lo.ttft_ms.mean < dis_lo.ttft_ms.mean,
        "colocated must win TTFT at low load: {} vs {}",
        colo_lo.ttft_ms.mean,
        dis_lo.ttft_ms.mean
    );
    assert!(dis_lo.transfer_overhead_share > 0.0);
    // High load: colocated ticks all carry prefill chunks; the decode pool's
    // do not — disaggregation must hold strictly lower p99 TPOT.
    let (colo_hi, dis_hi) = (run(colocated, 3000.0), run(disagg, 3000.0));
    assert!(colo_hi.completed > 0 && dis_hi.completed > 0);
    assert!(
        dis_hi.tpot_ms.p99 < colo_hi.tpot_ms.p99,
        "disaggregation must win p99 TPOT at high load: {} vs {}",
        dis_hi.tpot_ms.p99,
        colo_hi.tpot_ms.p99
    );
    // Seed stability: the high-load crossover point replays identically on
    // fresh caches.
    let trace = generate_trace(&TraceConfig::new(seed, TrafficPattern::Poisson, 3000.0, horizon));
    let ccfg = ClusterConfig { mode: disagg, ..ClusterConfig::colocated(2, &ds) };
    let (replay, _) =
        simulate_cluster(&sys, &ds, &trace, &ccfg, horizon, 3000.0, &KernelCache::new(), &StageTimeCache::new());
    assert_eq!(replay, dis_hi, "crossover point must be seed-stable");
}

/// Shared-pool interference anchor: with cross-model tick interference now
/// SIMULATED (both models' engines interleaved on one chip clock per
/// instance), shared-pool latencies must strictly dominate the old static
/// co-residency billing (reserved weights + split batch ceiling, no
/// interference) — the static rows were a lower bound, and the interleaved
/// fleet proves it. Seed-stable: the dominance holds on two seeds and the
/// interleaved pass replays bit-exactly.
#[test]
fn golden_cluster_models_interference_dominates_static_bound() {
    let sys = WaferSystem::paper();
    let big = DeepSeekConfig::v3_671b();
    let small = DeepSeekConfig::v3_16b();
    let horizon = 2.5;
    let base = ServeConfig::default();
    // The experiment's own co-residency billing recipe — pinning the recipe
    // AND the experiment to one definition (`cluster::co_resident_serve`).
    let shared_serve =
        |other: &DeepSeekConfig| flatattention::cluster::co_resident_serve(&sys, other, base);
    for seed in [7100u64, 911u64] {
        let kernels = KernelCache::new();
        let stages = StageTimeCache::new();
        let t_big = generate_trace(&TraceConfig::new(seed, TrafficPattern::Poisson, 150.0, horizon));
        let t_small =
            generate_trace(&TraceConfig::new(seed ^ 0x51AA, TrafficPattern::Poisson, 300.0, horizon));
        // Static lower bound: each model isolated on the ONE shared
        // instance with the co-residency taxes but NO tick interference.
        // A single instance makes routing trivially identical in both
        // arms, so the interleaved-vs-static delta is interference alone.
        let isolated = |ds: &DeepSeekConfig, t: &[flatattention::serve::Request], serve: ServeConfig| {
            let mut ccfg = ClusterConfig::colocated(1, ds);
            ccfg.serve = serve;
            let (o, _) = simulate_cluster(&sys, ds, t, &ccfg, horizon, 0.0, &kernels, &stages);
            assert!(o.conserves_requests());
            o
        };
        let static_big = isolated(&big, &t_big, shared_serve(&small));
        let static_small = isolated(&small, &t_small, shared_serve(&big));
        // Interleaved shared pool: identical configs, interference on.
        let specs = [
            SharedPoolSpec { ds: &big, trace: &t_big, serve: shared_serve(&small), offered_rps: 150.0 },
            SharedPoolSpec { ds: &small, trace: &t_small, serve: shared_serve(&big), offered_rps: 300.0 },
        ];
        let run = || {
            simulate_shared_pool(
                &sys,
                &specs,
                1,
                RoutingPolicy::LeastQueueDepth,
                Router::DEFAULT_DRAIN_RATE,
                horizon,
                &kernels,
                &stages,
            )
        };
        let shared = run();
        for (o, _) in &shared {
            assert!(o.conserves_requests(), "seed {seed}: {o:?}");
            assert!(o.completed > 0, "seed {seed}: shared pool must complete requests");
        }
        assert!(
            shared[0].0.tpot_ms.p99 > static_big.tpot_ms.p99,
            "seed {seed}: interleaved 671B p99 TPOT {} must strictly dominate the static bound {}",
            shared[0].0.tpot_ms.p99,
            static_big.tpot_ms.p99
        );
        assert!(
            shared[0].0.tpot_ms.p50 > static_big.tpot_ms.p50,
            "seed {seed}: the dominance is structural, not a tail artifact: {} vs {}",
            shared[0].0.tpot_ms.p50,
            static_big.tpot_ms.p50
        );
        assert!(
            shared[1].0.tpot_ms.p99 >= static_small.tpot_ms.p99,
            "seed {seed}: the 16B cannot be faster co-resident than isolated: {} vs {}",
            shared[1].0.tpot_ms.p99,
            static_small.tpot_ms.p99
        );
        // Bit-exact replay of the interleaved pass over the shared caches.
        let replay = run();
        assert_eq!(replay[0].0, shared[0].0, "seed {seed}");
        assert_eq!(replay[1].0, shared[1].0, "seed {seed}");
    }
}

/// Serving knee reproducibility: the `serve_load`-style sweep at a fixed
/// seed replays bit-exactly across fresh caches, and the Table II EP32-PP2
/// configuration exhibits a saturation knee inside the sweep.
#[test]
fn golden_serve_load_knee_is_reproducible() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let cfg = ServeConfig::default();
    let rates = [250.0, 1000.0, 4000.0];
    let run = || {
        load_sweep(
            &sys,
            &ds,
            &cfg,
            TrafficPattern::Poisson,
            &rates,
            2026,
            8.0,
            &KernelCache::new(),
            &StageTimeCache::new(),
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "fixed-seed sweep must replay bit-exactly");
    for o in &a {
        assert!(o.conserves_requests());
        assert!(!o.kv_over_capacity);
        assert!(o.completed > 0);
    }
    // Light load holds the SLO; the overdriven tail violates it.
    assert!(a[0].tpot_ms.p99 < cfg.slo_tpot_ms, "light-load p99 {}", a[0].tpot_ms.p99);
    assert!(
        a.last().unwrap().tpot_ms.p99 > cfg.slo_tpot_ms,
        "overload p99 {} should exceed the SLO",
        a.last().unwrap().tpot_ms.p99
    );
    let knee = saturation_knee(&a, cfg.slo_tpot_ms).expect("sweep must exhibit a knee");
    assert!(knee > rates[0] && knee <= rates[2], "knee at {knee} rps");
}
