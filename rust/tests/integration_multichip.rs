//! Wafer-scale end-to-end integration: the paper's §V-C claims as
//! qualitative invariants of the multichip model.

use flatattention::arch::config::SimFidelity;
use flatattention::baseline::soa::SoaSystem;
use flatattention::multichip::d2d::{D2dConfig, WaferSystem};
use flatattention::multichip::parallelism::{AttentionChoice, DecodeEvaluator, ParallelismPlan};
use flatattention::multichip::wafer::{batch_sweep, best_under_tpot, ep_plans, ours1, ours2};
use flatattention::workload::deepseek::DeepSeekConfig;

#[test]
fn table2_reproduction_shape() {
    // Ours1 beats DS-Prof on per-chip throughput AND TPOT under the 50 ms
    // constraint despite 1.5× lower peak system FLOPS.
    let ds_prof = SoaSystem::ds_prof();
    let sweep = ours1(SimFidelity::Analytic);
    let best = best_under_tpot(&sweep, 50.0).expect("operating point");
    assert!(best.per_chip_tokens_per_s > 2.0 * ds_prof.tokens_per_s_per_chip);
    assert!(best.tpot_ms < ds_prof.tpot_ms);
    // System-level: ≥1.5× throughput over the 96-chip DS-Prof system.
    let sys_speedup = best.system_tokens_per_s / ds_prof.system_tokens_per_s();
    assert!(sys_speedup > 1.5, "system speedup {sys_speedup}");
}

#[test]
fn table2_nvlink_class_still_wins() {
    let ds_prof = SoaSystem::ds_prof();
    let sweep = ours2(SimFidelity::Analytic);
    let best = best_under_tpot(&sweep, 50.0).expect("operating point");
    assert!(best.per_chip_tokens_per_s > 1.3 * ds_prof.tokens_per_s_per_chip);
}

#[test]
fn fig13a_flat_dominates_at_high_batch_not_low() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let plan = ParallelismPlan::new(32, 2);
    let flat = batch_sweep(&sys, &ds, plan, 4096, AttentionChoice::Flat, SimFidelity::Analytic);
    let mla = batch_sweep(&sys, &ds, plan, 4096, AttentionChoice::FlashMla, SimFidelity::Analytic);
    // FlatAttention dominates at every operating point. (The paper shows
    // parity at low batch because its FlashMLA baseline includes split-KV
    // latency optimization, which our FA-2-style mapping omits — see
    // EXPERIMENTS.md §fig13a.)
    let low = flat[0].system_tokens_per_s / mla[0].system_tokens_per_s;
    assert!(low > 1.0, "low-batch ratio {low}");
    // Paper operating point (b=256): a clear throughput win with lower TPOT.
    let f256 = flat.iter().find(|o| o.batch_per_chip == 256).unwrap();
    let m256 = mla.iter().find(|o| o.batch_per_chip == 256).unwrap();
    let hi = f256.system_tokens_per_s / m256.system_tokens_per_s;
    assert!(hi > 1.25, "b=256 speedup {hi}");
    assert!(f256.tpot_ms < m256.tpot_ms);
}

#[test]
fn fig13c_ep_dominates_pp_at_moderate_batch() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let mut ev = DecodeEvaluator::new(SimFidelity::Analytic);
    let mut best_tput = 0.0;
    let mut best_plan = String::new();
    for plan in ep_plans() {
        let o = ev.evaluate(&sys, &ds, plan, 128, 4096, AttentionChoice::Flat);
        if o.system_tokens_per_s > best_tput {
            best_tput = o.system_tokens_per_s;
            best_plan = plan.label();
        }
    }
    assert!(best_plan.starts_with("EP"), "best plan {best_plan} should use expert parallelism");
}

#[test]
fn fig13d_c2c_grows_with_ep_degree() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let mut ev = DecodeEvaluator::new(SimFidelity::Analytic);
    let mut last = 0.0;
    for plan in [ParallelismPlan::new(8, 8), ParallelismPlan::new(16, 4), ParallelismPlan::new(32, 2), ParallelismPlan::new(64, 1)] {
        let o = ev.evaluate(&sys, &ds, plan, 256, 4096, AttentionChoice::Flat);
        assert!(o.layer.c2c_s >= last, "{}: c2c {} < previous {last}", plan.label(), o.layer.c2c_s);
        last = o.layer.c2c_s;
    }
}

#[test]
fn pp_deepens_tpot_but_keeps_throughput() {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let mut ev = DecodeEvaluator::new(SimFidelity::Analytic);
    let ep64 = ev.evaluate(&sys, &ds, ParallelismPlan::new(64, 1), 128, 4096, AttentionChoice::Flat);
    let ep32pp2 = ev.evaluate(&sys, &ds, ParallelismPlan::new(32, 2), 128, 4096, AttentionChoice::Flat);
    // PP halves per-stage layer count: stage time roughly halves, TPOT is
    // similar (pp× the stage), and throughput is in the same ballpark.
    let r = ep32pp2.system_tokens_per_s / ep64.system_tokens_per_s;
    assert!(r > 0.4 && r < 2.5, "throughput ratio {r}");
}

#[test]
fn kv_cache_and_weights_fit_hbm_at_b256() {
    let ds = DeepSeekConfig::v3_671b();
    let kv = 256 * ds.kv_cache_bytes_per_user_layer(4096, flatattention::arch::config::Dtype::Fp8)
        * ds.layers as u64;
    let weights_ep32 = ds.param_count() / 32 + ds.param_count() / 10; // experts sharded + replicated rest
    assert!(kv + weights_ep32 < 128 * (1 << 30));
}

#[test]
fn d2d_group_dims_consistent_with_mesh() {
    let d = D2dConfig::wafer_8x8();
    for n in [1u32, 2, 4, 8, 16, 32, 64] {
        let (gx, gy) = d.group_dims(n);
        assert_eq!(gx * gy, n);
        assert!(gx <= d.mesh_x && gy <= d.mesh_y);
    }
}
