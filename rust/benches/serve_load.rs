//! Bench harness for the serving simulator: the full offered-load sweep
//! (3 traffic patterns × 6 load points), the KV-policy comparison, and the
//! prefix-cache / scheduling-policy experiment on shared-prompt traffic.
//! (criterion is unavailable in the offline build; this is a plain
//! `harness = false` driver with std timing.)

fn main() {
    // FLATATTENTION_FAST=1 shrinks every sweep to its test-scale parameters
    // (the CI smoke job runs the drivers with tiny horizons this way).
    let fast = std::env::var_os("FLATATTENTION_FAST").is_some();
    for id in ["serve_load", "serve_policies", "serve_prefix"] {
        let t0 = std::time::Instant::now();
        let rep = flatattention::coordinator::experiments::run(id, fast).expect("experiment");
        rep.print();
        println!("[bench {id}] regenerated in {:.2?}\n", t0.elapsed());
    }
}
