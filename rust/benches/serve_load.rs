//! Bench harness for the serving simulator: the full offered-load sweep
//! (3 traffic patterns × 6 load points), the KV-policy comparison, and the
//! prefix-cache / scheduling-policy experiment on shared-prompt traffic.
//! (criterion is unavailable in the offline build; this is a plain
//! `harness = false` driver with std timing.)
//!
//! With `--json-out PATH` or `FLATATTENTION_BENCH_JSON=<dir>` set, the wall
//! times also land in a `flatattention-bench-v1` JSON artifact so the perf
//! trajectory is machine-comparable across runs.

use flatattention::obs::report::{bench_json, bench_json_path, BenchRow};

fn main() {
    // FLATATTENTION_FAST=1 shrinks every sweep to its test-scale parameters
    // (the CI smoke job runs the drivers with tiny horizons this way).
    let fast = std::env::var_os("FLATATTENTION_FAST").is_some();
    let mut rows: Vec<BenchRow> = Vec::new();
    for id in ["serve_load", "serve_policies", "serve_prefix"] {
        let t0 = std::time::Instant::now();
        let rep = flatattention::coordinator::experiments::run(id, fast).expect("experiment");
        rep.print();
        let wall = t0.elapsed();
        println!("[bench {id}] regenerated in {wall:.2?}\n");
        rows.push(BenchRow { label: id.into(), shards: 1, sim_s: 0.0, wall_s: wall.as_secs_f64(), speedup: 1.0 });
    }
    if let Some(path) = bench_json_path("serve_load") {
        let config = format!("fast={fast}");
        std::fs::write(&path, bench_json("serve_load", &config, &rows)).expect("write bench json");
        println!("[bench serve_load] json → {}", path.display());
    }
}
