//! Bench harness regenerating the paper's Fig. 13 (a–d): DeepSeek-v3-671B
//! decoding on the wafer-scale system.
//! (criterion is unavailable in the offline build; this is a plain
//! `harness = false` driver with std timing.)

fn main() {
    for id in ["fig13a", "fig13b", "fig13c", "fig13d"] {
        let t0 = std::time::Instant::now();
        let rep = flatattention::coordinator::experiments::run(id, false).expect("experiment");
        rep.print();
        println!("[bench {id}] regenerated in {:.2?}\n", t0.elapsed());
    }
}
