//! Bench harness regenerating the paper's Fig. 13 (a–d): DeepSeek-v3-671B
//! decoding on the wafer-scale system.
//! (criterion is unavailable in the offline build; this is a plain
//! `harness = false` driver with std timing.)

fn main() {
    // FLATATTENTION_FAST=1 shrinks every sweep to its test-scale parameters
    // (the CI smoke job runs the drivers with tiny horizons this way).
    let fast = std::env::var_os("FLATATTENTION_FAST").is_some();
    for id in ["fig13a", "fig13b", "fig13c", "fig13d"] {
        let t0 = std::time::Instant::now();
        let rep = flatattention::coordinator::experiments::run(id, fast).expect("experiment");
        rep.print();
        println!("[bench {id}] regenerated in {:.2?}\n", t0.elapsed());
    }
}
