//! Bench harness for the fleet layer: the full prefill:decode pool-ratio
//! sweep (4 configurations × load points on a 4-instance fleet), the
//! multi-model co-serving comparison (interleaved shared pools vs the
//! static bound), the static-vs-live routing comparison, the shard-count
//! scaling sweep of the conservative-lookahead engine (a fixed large
//! colocated fleet at 1/2/4/8 shards, reporting
//! simulated-seconds-per-wall-second), and the KV-fabric topology sweep
//! (degenerate vs torus vs fat-tree at 16/64 instances, tracking
//! per-topology p99 TTFT and mean link wait in the `BENCH_*.json`
//! trajectory). (criterion is unavailable in the offline build; this is a
//! plain `harness = false` driver with std timing.)

use flatattention::cluster::{simulate_cluster, ClusterConfig, RoutingPolicy, TopologySpec};
use flatattention::multichip::d2d::WaferSystem;
use flatattention::multichip::parallelism::KernelCache;
use flatattention::obs::report::{bench_json, bench_json_path, BenchRow};
use flatattention::serve::request::{generate_trace, PrefixProfile, TraceConfig, TrafficPattern};
use flatattention::serve::sim::StageTimeCache;
use flatattention::workload::deepseek::DeepSeekConfig;

fn main() {
    // FLATATTENTION_FAST=1 shrinks every sweep to its test-scale parameters
    // (the CI smoke job runs the drivers with tiny horizons this way).
    let fast = std::env::var_os("FLATATTENTION_FAST").is_some();
    let mut rows: Vec<BenchRow> = Vec::new();
    for id in ["cluster_pools", "cluster_models", "cluster_dynamic"] {
        let t0 = std::time::Instant::now();
        let rep = flatattention::coordinator::experiments::run(id, fast).expect("experiment");
        rep.print();
        let wall = t0.elapsed();
        println!("[bench {id}] regenerated in {wall:.2?}\n");
        rows.push(BenchRow { label: id.into(), shards: 1, sim_s: 0.0, wall_s: wall.as_secs_f64(), speedup: 1.0 });
    }
    rows.extend(shard_sweep(fast));
    rows.extend(topology_sweep(fast));
    if let Some(path) = bench_json_path("cluster_pools") {
        let config = format!("fast={fast}");
        std::fs::write(&path, bench_json("cluster_pools", &config, &rows)).expect("write bench json");
        println!("[bench cluster_pools] json → {}", path.display());
    }
}

/// Shard-count scaling of the sharded conservative-lookahead fleet engine:
/// one fixed saturated colocated fleet replayed at 1/2/4/8 shards. Every
/// run must agree with the serial reference (the engine is bit-identical
/// at any shard count); the interesting number is
/// simulated-seconds-per-wall-second. Returns one [`BenchRow`] per shard
/// count for the structured `BENCH_*.json` artifact.
fn shard_sweep(fast: bool) -> Vec<BenchRow> {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    // Full scale: a 64-instance fleet driven at the per-instance saturation
    // point of `cluster_pools` (2000 rps/instance overdrives 4 instances at
    // 8000 rps; 125 rps/instance keeps 64 instances busy without an
    // unbounded backlog).
    let (instances, rate, horizon) = if fast { (8u32, 400.0, 2.0) } else { (64u32, 8000.0, 10.0) };
    let trace = generate_trace(
        &TraceConfig::new(2026, TrafficPattern::Poisson, rate, horizon).with_prefixes(PrefixProfile::agentic()),
    );
    let kernels = KernelCache::new();
    let stages = StageTimeCache::new();
    let mut cfg = ClusterConfig::colocated(instances, &ds);
    // Warm the shared kernel/stage memo caches so the timed runs measure
    // the fleet engine, not first-touch kernel simulation.
    let (reference, _) = simulate_cluster(&sys, &ds, &trace, &cfg, horizon, rate, &kernels, &stages);
    println!(
        "[bench shard_sweep] {instances} colocated instances, {rate:.0} rps over {horizon} s ({} requests)",
        trace.len()
    );
    let mut serial_wall = f64::NAN;
    let mut rows = Vec::new();
    for shards in [1u32, 2, 4, 8] {
        cfg.shards = shards;
        let t0 = std::time::Instant::now();
        let (o, _) = simulate_cluster(&sys, &ds, &trace, &cfg, horizon, rate, &kernels, &stages);
        let wall = t0.elapsed().as_secs_f64();
        if shards == 1 {
            serial_wall = wall;
        }
        assert_eq!(o.completed, reference.completed, "sharded run diverged from serial");
        assert_eq!(o.arrived, reference.arrived, "sharded run diverged from serial");
        println!(
            "[bench shard_sweep] shards={shards}: wall {wall:.3} s, {:.1} sim-s/wall-s, {:.2}x vs serial",
            horizon / wall,
            serial_wall / wall
        );
        rows.push(BenchRow {
            label: format!("shard_sweep instances={instances} rate={rate:.0}"),
            shards,
            sim_s: horizon,
            wall_s: wall,
            speedup: serial_wall / wall,
        });
    }
    rows
}

/// KV-fabric topology trajectory: the same disaggregated handoff traffic
/// routed over the pooled degenerate switch, a 2D torus, and a two-level
/// fat-tree, at 16 and 64 instances with hop-aware decode placement. The
/// networking numbers the `BENCH_*.json` artifact starts tracking are
/// carried in the row label (`flatattention-bench-v1` has no free-form
/// metric fields): per-topology p99 TTFT (ms) and mean per-migration link
/// wait (ms).
fn topology_sweep(fast: bool) -> Vec<BenchRow> {
    let sys = WaferSystem::paper();
    let ds = DeepSeekConfig::v3_671b();
    let fleets: &[u32] = if fast { &[4] } else { &[16, 64] };
    let (rate_per_instance, horizon) = if fast { (100.0, 2.0) } else { (150.0, 6.0) };
    let kernels = KernelCache::new();
    let stages = StageTimeCache::new();
    let mut rows = Vec::new();
    for &instances in fleets {
        let rate = rate_per_instance * instances as f64;
        let trace = generate_trace(
            &TraceConfig::new(2026, TrafficPattern::Poisson, rate, horizon).with_prefixes(PrefixProfile::agentic()),
        );
        for topo in [TopologySpec::Degenerate, TopologySpec::Torus, TopologySpec::FatTree] {
            let mut cfg = ClusterConfig::disaggregated(instances / 2, instances - instances / 2, &ds);
            cfg.topology = topo;
            cfg.decode_routing = RoutingPolicy::TopoAware;
            let t0 = std::time::Instant::now();
            let (o, _) = simulate_cluster(&sys, &ds, &trace, &cfg, horizon, rate, &kernels, &stages);
            let wall = t0.elapsed().as_secs_f64();
            let wait_ms = o.link_wait_s * 1e3 / o.migrated.max(1) as f64;
            println!(
                "[bench topology_sweep] {} instances={instances}: p99 TTFT {:.0} ms, link wait {wait_ms:.2} \
                 ms/migration, {} hops over {} edges, wall {wall:.3} s",
                topo.label(),
                o.ttft_ms.p99,
                o.fabric_hops,
                o.edge_busy_s.len()
            );
            rows.push(BenchRow {
                label: format!(
                    "topology_sweep topo={} instances={instances} ttft_p99_ms={:.1} link_wait_ms={wait_ms:.3}",
                    topo.label(),
                    o.ttft_ms.p99
                ),
                shards: 1,
                sim_s: horizon,
                wall_s: wall,
                speedup: 1.0,
            });
        }
    }
    rows
}
