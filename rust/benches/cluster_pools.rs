//! Bench harness for the fleet layer: the full prefill:decode pool-ratio
//! sweep (4 configurations × load points on a 4-instance fleet) and the
//! multi-model co-serving comparison. (criterion is unavailable in the
//! offline build; this is a plain `harness = false` driver with std
//! timing.)

fn main() {
    for id in ["cluster_pools", "cluster_models"] {
        let t0 = std::time::Instant::now();
        let rep = flatattention::coordinator::experiments::run(id, false).expect("experiment");
        rep.print();
        println!("[bench {id}] regenerated in {:.2?}\n", t0.elapsed());
    }
}
