//! Bench harness for the fleet layer: the full prefill:decode pool-ratio
//! sweep (4 configurations × load points on a 4-instance interleaved
//! fleet), the multi-model co-serving comparison (interleaved shared pools
//! vs the static bound), and the static-vs-live routing comparison.
//! (criterion is unavailable in the offline build; this is a plain
//! `harness = false` driver with std timing.)

fn main() {
    // FLATATTENTION_FAST=1 shrinks every sweep to its test-scale parameters
    // (the CI smoke job runs the drivers with tiny horizons this way).
    let fast = std::env::var_os("FLATATTENTION_FAST").is_some();
    for id in ["cluster_pools", "cluster_models", "cluster_dynamic"] {
        let t0 = std::time::Instant::now();
        let rep = flatattention::coordinator::experiments::run(id, fast).expect("experiment");
        rep.print();
        println!("[bench {id}] regenerated in {:.2?}\n", t0.elapsed());
    }
}
