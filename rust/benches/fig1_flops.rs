//! Bench harness regenerating the paper's Fig. 1a FLOP breakdown.
//! Runs the experiment at full parameter scale and reports wall time.
//! (criterion is unavailable in the offline build; this is a plain
//! `harness = false` driver with std timing.)

fn main() {
    let t0 = std::time::Instant::now();
    let rep = flatattention::coordinator::experiments::run("fig1a", false).expect("experiment");
    rep.print();
    println!("\n[bench {}] regenerated in {:.2?}", "fig1a", t0.elapsed());
}
