//! Bench harness regenerating the paper's Fig. 7 collective-primitive latency comparison.
//! Runs the experiment at full parameter scale and reports wall time.
//! (criterion is unavailable in the offline build; this is a plain
//! `harness = false` driver with std timing.)

fn main() {
    let t0 = std::time::Instant::now();
    let rep = flatattention::coordinator::experiments::run("fig7", false).expect("experiment");
    rep.print();
    println!("\n[bench {}] regenerated in {:.2?}", "fig7", t0.elapsed());
}
