//! Bench harness regenerating the paper's Fig. 9 group-scale (over-flattening) trade-off.
//! Runs the experiment at full parameter scale and reports wall time.
//! (criterion is unavailable in the offline build; this is a plain
//! `harness = false` driver with std timing.)

fn main() {
    // FLATATTENTION_FAST=1 shrinks every sweep to its test-scale parameters
    // (the CI smoke job runs the drivers with tiny horizons this way).
    let fast = std::env::var_os("FLATATTENTION_FAST").is_some();
    let t0 = std::time::Instant::now();
    let rep = flatattention::coordinator::experiments::run("fig9", fast).expect("experiment");
    rep.print();
    println!("\n[bench {}] regenerated in {:.2?}", "fig9", t0.elapsed());
}
